//! Randomized property tests for speculative decoding (in-tree generator
//! over `Pcg64` — proptest is unavailable offline; the methodology is the
//! same: many random cases per invariant, failing seed printed on panic).
//! Runs hermetically: no artifacts, no PJRT.
//!
//! Invariants:
//! * **exact greedy equivalence** — greedy speculative output is
//!   token-for-token *identical* (`assert_eq!`, not a tolerance) to plain
//!   greedy decoding of the target, across random model configs, prompt
//!   lengths, `k ∈ {1..4}`, SVD and Random-solver drafts, and adaptive-k.
//!   This is the PR's headline contract: the draft model may only ever
//!   change how fast the stream is produced, never what it says;
//! * **sampled-mode marginal sanity** — with seeded rejection sampling the
//!   emitted tokens are `p_target`-distributed: the empirical distribution
//!   of a spec-emitted position over many seeds matches plain sampled
//!   decoding of the target in total-variation distance, even under a
//!   deliberately bad (Random-solver) draft where most drafts are rejected;
//! * **rollback exactness** — after every draft→verify→rollback round the
//!   target's KV cache is bit-identical (`==` on the raw f32 slices) to a
//!   fresh session replayed on exactly the accepted prefix: rollback
//!   leaves no residue.

use greenformer::backend::native::{init_text_params, synth_fwd_graph, TextModelCfg};
use greenformer::backend::{
    build_draft_params, generate, generate_speculative, Backend, DecodeSession, NativeBackend,
    SamplingCfg, SpecConfig, SpecSession,
};
use greenformer::factorize::{auto_fact, AutoFactConfig, Rank, Solver};
use greenformer::runtime::GraphSpec;
use greenformer::tensor::ParamStore;
use greenformer::util::Pcg64;

/// Random small LM dims. `d >= 18` so the Eq.-1 gate (MIN_RANK = 8) accepts
/// the attention/FFN layers of the draft factorization.
fn rand_lm_cfg(rng: &mut Pcg64) -> TextModelCfg {
    let heads = if rng.below(2) == 0 { 3 } else { 4 };
    let dk = 6 + rng.below(4); // 6..=9 → d in 18..=36
    let vocab = 32 + rng.below(33);
    TextModelCfg {
        vocab,
        seq: 8 + rng.below(7),
        d: heads * dk,
        heads,
        layers: 1 + rng.below(2),
        ff: 24 + rng.below(33),
        classes: vocab, // head width = vocab: causal LM
    }
}

/// Synthesized LM graph with the cfg's actual head count stamped in (the
/// zoo default of 6 is not recoverable from the parameters).
fn lm_graph(cfg: &TextModelCfg, variant: &str, params: &ParamStore) -> GraphSpec {
    let mut g = synth_fwd_graph("lm", variant, 1, params).unwrap();
    g.config.insert("heads".to_string(), cfg.heads);
    g
}

/// A deliberately unfaithful draft: Random-solver factors approximate
/// nothing, so the target rejects most proposals — the stress case for the
/// rollback and residual-sampling paths.
fn random_solver_draft(params: &ParamStore, seed: u64) -> ParamStore {
    let mut draft = params.clone();
    let report = auto_fact(
        &mut draft,
        &AutoFactConfig {
            rank: Rank::Ratio(0.5),
            solver: Solver::Random,
            num_iter: 0,
            submodules: None,
            ..Default::default()
        },
    )
    .unwrap();
    assert!(report.n_factorized() > 0, "seed {seed}: cfg too small for the Eq.-1 gate");
    draft
}

#[test]
fn greedy_speculative_stream_is_exactly_plain_greedy() {
    let be = NativeBackend::new();
    let greedy = SamplingCfg::greedy();
    for seed in 0..8u64 {
        let mut rng = Pcg64::new(seed, 410);
        let cfg = rand_lm_cfg(&mut rng);
        let params = init_text_params(&cfg, seed ^ 0xC0);
        let g = lm_graph(&cfg, "dense", &params);
        // Alternate a faithful draft (SVD — high acceptance) with a
        // garbage draft (Random solver — constant rejection): greedy
        // equivalence must hold for BOTH, because the accept rule compares
        // the target against itself.
        let draft = if seed % 2 == 0 {
            build_draft_params(&params, 0.5).unwrap()
        } else {
            random_solver_draft(&params, seed)
        };
        let spec = SpecConfig {
            draft_ratio: 0.5,
            k: 1 + (seed as usize % 4),
            adaptive_k: seed % 3 == 0,
        };
        let plen = 1 + rng.below(cfg.seq - 2);
        let prompt: Vec<i32> = (0..plen).map(|_| rng.below(cfg.vocab) as i32).collect();
        let max_new = 1 + rng.below(8);

        let plain = generate(&be, &g, &params, &prompt, max_new, &greedy, |_, _| {}).unwrap();
        let mut streamed = Vec::new();
        let spec_out = generate_speculative(
            &be, &g, &params, &g, &draft, &prompt, max_new, &greedy, &spec, |i, t| {
                assert_eq!(i, streamed.len(), "seed {seed}: stream indices out of order");
                streamed.push(t);
            },
        )
        .unwrap();

        // Bit-for-bit token identity — the whole point of the PR.
        assert_eq!(
            spec_out.tokens, plain.tokens,
            "seed {seed} (k={}, adaptive={}): speculative greedy diverged from plain greedy",
            spec.k, spec.adaptive_k
        );
        assert_eq!(spec_out.tokens, streamed, "seed {seed}: callback stream != outcome");
        assert_eq!(
            spec_out.positions_used, plain.positions_used,
            "seed {seed}: cache occupancy diverged"
        );
        // Ledger invariant: every emitted token is an accepted draft or a
        // target-sampled correction/bonus.
        assert_eq!(
            spec_out.accepted + spec_out.corrections,
            spec_out.tokens.len() as u64,
            "seed {seed}: speculation ledger does not reconcile"
        );
        assert!(
            spec_out.accepted <= spec_out.drafted,
            "seed {seed}: accepted more than drafted"
        );
    }
}

#[test]
fn sampled_speculative_marginal_matches_plain_sampling() {
    // The rejection-sampling accept rule promises each emitted token is
    // exactly p_target-distributed no matter how bad the draft is. Check
    // the marginal of the first round-emitted position (index 1: index 0
    // is the shared prefill sample) over many seeds against plain sampled
    // decoding, under a Random-solver draft that gets rejected constantly.
    let be = NativeBackend::new();
    let cfg = TextModelCfg {
        vocab: 32,
        seq: 12,
        d: 24,
        heads: 6,
        layers: 1,
        ff: 32,
        classes: 32,
    };
    let params = init_text_params(&cfg, 99);
    let g = lm_graph(&cfg, "dense", &params);
    let draft = random_solver_draft(&params, 99);
    let spec = SpecConfig { draft_ratio: 0.5, k: 2, adaptive_k: false };
    let prompt = [3i32, 7, 11];
    const RUNS: usize = 400;

    let mut plain_hist = vec![0usize; cfg.vocab];
    let mut spec_hist = vec![0usize; cfg.vocab];
    for seed in 0..RUNS as u64 {
        let sampling = SamplingCfg { temperature: 0.7, top_k: 8, seed };
        let plain = generate(&be, &g, &params, &prompt, 3, &sampling, |_, _| {}).unwrap();
        plain_hist[plain.tokens[1] as usize] += 1;
        let sp = generate_speculative(
            &be, &g, &params, &g, &draft, &prompt, 3, &sampling, &spec, |_, _| {},
        )
        .unwrap();
        spec_hist[sp.tokens[1] as usize] += 1;
    }
    let tv: f64 = plain_hist
        .iter()
        .zip(&spec_hist)
        .map(|(&a, &b)| (a as f64 - b as f64).abs())
        .sum::<f64>()
        / (2.0 * RUNS as f64);
    assert!(
        tv < 0.2,
        "sampled speculative marginal drifted from plain sampling: TV distance {tv:.3} \
         (plain {plain_hist:?} vs spec {spec_hist:?})"
    );
}

#[test]
fn rollback_leaves_target_cache_identical_to_fresh_replay() {
    let be = NativeBackend::new();
    let greedy = SamplingCfg::greedy();
    let mut total_rolled = 0usize;
    for seed in 0..6u64 {
        let mut rng = Pcg64::new(seed, 412);
        let cfg = rand_lm_cfg(&mut rng);
        let params = init_text_params(&cfg, seed ^ 0xD1);
        let g = lm_graph(&cfg, "dense", &params);
        // Random-solver draft: approximates nothing, so verify rejects
        // most drafts and every step exercises the truncation path.
        let draft = random_solver_draft(&params, seed);
        let spec = SpecConfig { draft_ratio: 0.5, k: 3, adaptive_k: false };
        let plen = 1 + rng.below(cfg.seq / 2);
        let prompt: Vec<i32> = (0..plen).map(|_| rng.below(cfg.vocab) as i32).collect();

        let (mut session, first) =
            SpecSession::new(&be, &g, &params, &g, &draft, &prompt, greedy, &spec).unwrap();
        let mut emitted = vec![first];
        let budget = 8usize;
        while emitted.len() < budget && session.target().remaining() > 0 {
            let step = session
                .step(&be, &g, &params, &g, &draft, budget - emitted.len())
                .unwrap();
            emitted.extend_from_slice(&step.tokens);
            total_rolled += step.rolled_back;

            // Invariant: the target cache holds exactly the accepted
            // prefix — prompt + every emitted token except the newest
            // (sampled but not yet appended, like plain generate). Replay
            // that prefix on a fresh session and demand bit-identical k/v.
            let mut fresh = DecodeSession::new(&g, &params).unwrap();
            be.run_decode_step(&g, &params, &mut fresh, &prompt).unwrap();
            for &t in &emitted[..emitted.len() - 1] {
                be.run_decode_step(&g, &params, &mut fresh, &[t]).unwrap();
            }
            let target = session.target();
            assert_eq!(target.len(), fresh.len(), "seed {seed}: cache length after rollback");
            assert_eq!(target.num_layers(), fresh.num_layers(), "seed {seed}");
            for layer in 0..target.num_layers() {
                let (tk, tv) = target.layer_kv(layer).unwrap();
                let (fk, fv) = fresh.layer_kv(layer).unwrap();
                assert!(
                    tk == fk && tv == fv,
                    "seed {seed} layer {layer}: post-rollback KV cache != fresh replay \
                     (step drafted {} accepted {} rolled_back {})",
                    step.drafted,
                    step.accepted,
                    step.rolled_back
                );
            }
        }
        // The ledger must reconcile on the session accessors too.
        assert_eq!(
            session.accepted() + session.corrections(),
            emitted.len() as u64,
            "seed {seed}: session ledger does not reconcile"
        );
    }
    // The Random-solver draft must actually have exercised rollback —
    // otherwise this test silently proves nothing.
    assert!(total_rolled > 0, "no rollback ever happened across all seeds");
}
