//! Randomized property tests for continuous-batching decode (in-tree
//! generator over `Pcg64` — proptest is unavailable offline; the
//! methodology is the same: many random cases per invariant, failing seed
//! printed on panic). Runs hermetically: no artifacts, no PJRT.
//!
//! Invariants:
//! * a stacked `run_decode_step_batched` over m concurrent sessions produces,
//!   for every session, logits identical (within 1e-5 — in practice
//!   bit-identical, see `backend::decode`) to advancing that session alone
//!   with solo `run_decode_step` calls, for dense **and** LED models, under
//!   a schedule where streams join late and leave early (dynamic
//!   join/leave, the coordinator's sweep shape);
//! * `generate_batched` reproduces `generate` stream-for-stream under mixed
//!   sampling policies.

use greenformer::backend::native::{init_text_params, synth_fwd_graph, TextModelCfg};
use greenformer::backend::{
    generate, generate_batched, Backend, DecodeSession, NativeBackend, SamplingCfg,
};
use greenformer::factorize::{auto_fact, AutoFactConfig, Rank, Solver};
use greenformer::runtime::GraphSpec;
use greenformer::tensor::ParamStore;
use greenformer::util::Pcg64;

const TOL: f32 = 1e-5;

/// Random small LM dims. `d >= 18` so the Eq.-1 gate (MIN_RANK = 8) accepts
/// the attention/FFN layers of the LED cases.
fn rand_lm_cfg(rng: &mut Pcg64) -> TextModelCfg {
    let heads = if rng.below(2) == 0 { 3 } else { 4 };
    let dk = 6 + rng.below(4); // 6..=9 → d in 18..=36
    let vocab = 32 + rng.below(33);
    TextModelCfg {
        vocab,
        seq: 8 + rng.below(7),
        d: heads * dk,
        heads,
        layers: 1 + rng.below(2),
        ff: 24 + rng.below(33),
        classes: vocab, // head width = vocab: causal LM
    }
}

/// Synthesized LM graph with the cfg's actual head count stamped in (the
/// zoo default of 6 is not recoverable from the parameters).
fn lm_graph(cfg: &TextModelCfg, variant: &str, params: &ParamStore) -> GraphSpec {
    let mut g = synth_fwd_graph("lm", variant, 1, params).unwrap();
    g.config.insert("heads".to_string(), cfg.heads);
    g
}

/// Random-solver LED factorization at Ratio(0.5); panics if the random cfg
/// was too small for any layer to pass the Eq.-1 gate.
fn factorize(params: &mut ParamStore, seed: u64) {
    let report = auto_fact(
        params,
        &AutoFactConfig {
            rank: Rank::Ratio(0.5),
            solver: Solver::Random,
            num_iter: 0,
            submodules: None,
            ..Default::default()
        },
    )
    .unwrap();
    assert!(report.n_factorized() > 0, "seed {seed}: cfg too small for the Eq.-1 gate");
}

/// One simulated stream: mirrored sessions (one advanced through the
/// stacked batched step, one through solo steps) fed identical tokens on an
/// identical schedule.
struct Stream {
    /// Global step at which the stream prefills and joins the batch.
    start: usize,
    /// Batched token steps the stream runs before leaving.
    steps: usize,
    prompt: Vec<i32>,
    batched: Option<DecodeSession>,
    solo: Option<DecodeSession>,
}

#[test]
fn stacked_step_matches_solo_steps_with_staggered_join_leave() {
    let be = NativeBackend::new();
    for seed in 0..10u64 {
        let mut rng = Pcg64::new(seed, 310);
        let cfg = rand_lm_cfg(&mut rng);
        let mut params = init_text_params(&cfg, seed ^ 0xBA);
        let mut variant = "dense";
        if seed % 2 == 1 {
            // LED case: the batched path must dispatch a/b factors per layer.
            factorize(&mut params, seed);
            variant = "led_r50";
        }
        let g = lm_graph(&cfg, variant, &params);

        // 2–4 streams with random prompts, random join times and random
        // step budgets bounded by each stream's positional headroom.
        let n_streams = 2 + rng.below(3);
        let mut streams: Vec<Stream> = (0..n_streams)
            .map(|_| {
                let plen = 1 + rng.below(cfg.seq - 2);
                let room = cfg.seq - plen;
                Stream {
                    start: rng.below(3),
                    steps: 1 + rng.below(room),
                    prompt: (0..plen).map(|_| rng.below(cfg.vocab) as i32).collect(),
                    batched: None,
                    solo: None,
                }
            })
            .collect();
        let last_step = streams.iter().map(|s| s.start + s.steps).max().unwrap();

        for t in 0..last_step {
            // Join phase: prefill both replicas of streams starting now and
            // check they agree from the first logits on.
            for (i, st) in streams.iter_mut().enumerate() {
                if st.start != t {
                    continue;
                }
                let mut b = DecodeSession::new(&g, &params).unwrap();
                let lb = be.run_decode_step(&g, &params, &mut b, &st.prompt).unwrap();
                let mut s = DecodeSession::new(&g, &params).unwrap();
                let ls = be.run_decode_step(&g, &params, &mut s, &st.prompt).unwrap();
                for (a, c) in lb.as_f32().unwrap().iter().zip(ls.as_f32().unwrap()) {
                    assert!((a - c).abs() <= TOL, "seed {seed} ({variant}) stream {i} prefill");
                }
                st.batched = Some(b);
                st.solo = Some(s);
            }

            // Live streams this step (joined, not yet out of budget) get one
            // shared random token each.
            let live: Vec<usize> = streams
                .iter()
                .enumerate()
                .filter(|(_, s)| s.start <= t && t < s.start + s.steps)
                .map(|(i, _)| i)
                .collect();
            if live.is_empty() {
                continue;
            }
            let toks: Vec<i32> =
                live.iter().map(|_| rng.below(cfg.vocab) as i32).collect();

            // Stacked step over all live streams at once (`live` is
            // ascending, so this single `iter_mut` pass matches its order)...
            let stacked = {
                let mut refs: Vec<&mut DecodeSession> = streams
                    .iter_mut()
                    .enumerate()
                    .filter(|(i, _)| live.contains(i))
                    .map(|(_, st)| st.batched.as_mut().unwrap())
                    .collect();
                be.run_decode_step_batched(&g, &params, &mut refs, &toks).unwrap()
            };
            // ...must match each stream's solo step on the same token.
            for ((&i, tok), logits) in live.iter().zip(&toks).zip(&stacked) {
                let st = &mut streams[i];
                let solo = be
                    .run_decode_step(&g, &params, st.solo.as_mut().unwrap(), &[*tok])
                    .unwrap();
                for (j, (a, c)) in logits
                    .as_f32()
                    .unwrap()
                    .iter()
                    .zip(solo.as_f32().unwrap())
                    .enumerate()
                {
                    assert!(
                        (a - c).abs() <= TOL,
                        "seed {seed} ({variant}) stream {i} step {t} logit {j}: \
                         batched {a} vs solo {c}"
                    );
                }
                assert_eq!(
                    st.batched.as_ref().unwrap().len(),
                    st.solo.as_ref().unwrap().len(),
                    "seed {seed} stream {i}: cache lengths diverged"
                );
            }
        }
        // Every stream ran its full schedule.
        for (i, st) in streams.iter().enumerate() {
            let got = st.batched.as_ref().unwrap().len();
            assert_eq!(
                got,
                st.prompt.len() + st.steps,
                "seed {seed} stream {i}: expected full schedule"
            );
        }
    }
}

#[test]
fn generate_batched_reproduces_generate_per_stream() {
    let be = NativeBackend::new();
    for seed in 0..6u64 {
        let mut rng = Pcg64::new(seed, 311);
        let cfg = rand_lm_cfg(&mut rng);
        let mut params = init_text_params(&cfg, seed ^ 0x77);
        let mut variant = "dense";
        if seed % 2 == 1 {
            factorize(&mut params, seed);
            variant = "led_r50";
        }
        let g = lm_graph(&cfg, variant, &params);

        let n = 2 + rng.below(3);
        let prompts: Vec<Vec<i32>> = (0..n)
            .map(|_| {
                let plen = 1 + rng.below(cfg.seq - 1);
                (0..plen).map(|_| rng.below(cfg.vocab) as i32).collect()
            })
            .collect();
        let cfgs: Vec<SamplingCfg> = (0..n)
            .map(|i| {
                if i % 2 == 0 {
                    SamplingCfg::greedy()
                } else {
                    SamplingCfg { temperature: 0.9, top_k: 8, seed: seed * 31 + i as u64 }
                }
            })
            .collect();
        let max_new = 1 + rng.below(6);

        let batched = generate_batched(&be, &g, &params, &prompts, max_new, &cfgs).unwrap();
        for (i, ((prompt, s), out)) in prompts.iter().zip(&cfgs).zip(&batched).enumerate() {
            let solo = generate(&be, &g, &params, prompt, max_new, s, |_, _| {}).unwrap();
            assert_eq!(
                out.tokens, solo.tokens,
                "seed {seed} ({variant}) stream {i}: batched stream diverged from solo"
            );
            assert_eq!(out.positions_used, solo.positions_used, "seed {seed} stream {i}");
            assert_eq!(out.prefill_tokens, prompt.len(), "seed {seed} stream {i}");
        }
    }
}
