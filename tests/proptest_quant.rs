//! Quantization-correctness property layer (DESIGN.md §12).
//!
//! Four pins, from kernel to end-to-end:
//!
//! (a) The dispatching int8 GEMM (`qmatmul_bias_into`) is **bit-identical**
//!     to the scalar reference over adversarial shapes — m=1 (decode GEMV),
//!     k=0 (epilogue-only), non-divisible tile remainders, and shapes large
//!     enough to take the packed-serial and pooled paths. i8×i8→i32
//!     accumulation is exact and order-free, and every path performs the
//!     identical single f32 dequant per element, so equality is exact, not
//!     approximate.
//! (b) Per-output-channel quantize→dequantize error is ≤ scale/2 per
//!     element, and the per-channel scale is exactly `maxabs/127` on
//!     single-channel inputs.
//! (c) The binary ±1 popcount matvec equals the f32 matvec **exactly** on
//!     ±1 matrices (`k − 2·popcount` arithmetic is integer-exact in f32).
//! (d) End to end: int8 LED decode logits stay within the
//!     `quantize_led_params` report's propagated worst-case bound, and the
//!     greedy token streams match f32 on ≥ 18 of 20 seeded configs
//!     (constants calibrated offline; divergent seeds are printed).
//!
//! In-tree generator (`util::Pcg64`), same methodology note as
//! proptest_coordinator.rs.

use std::sync::Arc;

use greenformer::backend::native::{init_text_params, synth_fwd_graph, TextModelCfg};
use greenformer::backend::{
    generate_with_session, Backend, DecodeSession, NativeBackend, SamplingCfg,
};
use greenformer::factorize::{
    auto_fact, quantize_led_params, AutoFactConfig, Rank, Solver, WeightPrecision,
};
use greenformer::linalg::quant::{binarize_row_into, quant_scale};
use greenformer::linalg::{
    qmatmul_bias_into, qmatmul_into_reference, quantize_rows_into, Activation, BinaryMatrix,
    QuantizedMatrix,
};
use greenformer::util::Pcg64;

fn rand_vec(rng: &mut Pcg64, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| (rng.next_f32() * 2.0 - 1.0) * scale).collect()
}

// ---------------------------------------------------------------------------
// (a) dispatching int8 GEMM ≡ scalar reference, bit for bit
// ---------------------------------------------------------------------------

#[test]
fn int8_gemm_bitwise_equals_reference_on_adversarial_shapes() {
    let mut rng = Pcg64::seeded(41);
    // Forced corners: the m=1 GEMV path, k=0 epilogue-only, every-axis tile
    // remainders (MR=NR=8), the packed-serial threshold (≥ 2^15 MACs) and
    // the pooled threshold (≥ 2^19 MACs, pool-vs-serial agreement).
    let mut shapes = vec![
        (1, 7, 9),
        (1, 64, 256),
        (1, 0, 5),
        (3, 0, 4),
        (2, 5, 1),
        (8, 8, 8),
        (9, 13, 17),
        (33, 40, 31),
        (96, 80, 96),
    ];
    for _ in 0..12 {
        shapes.push((1 + rng.below(24), rng.below(48), 1 + rng.below(40)));
    }
    for (case, &(m, k, n)) in shapes.iter().enumerate() {
        let w = rand_vec(&mut rng, k * n, 2.0);
        let x = rand_vec(&mut rng, m * k, 3.0);
        let qw = QuantizedMatrix::from_f32(k, n, &w);
        let mut xq = Vec::new();
        let mut xscale = Vec::new();
        quantize_rows_into(m, k, &x, &mut xq, &mut xscale);
        let bias = rand_vec(&mut rng, n, 0.5);
        for act in [Activation::None, Activation::Gelu, Activation::Relu] {
            for b in [None, Some(bias.as_slice())] {
                // Both sides accumulate (`+=`) into the same nonzero
                // baseline so the pre-existing-output path is pinned too.
                let base = rand_vec(&mut rng, m * n, 0.25);
                let mut got = base.clone();
                let mut want = base;
                qmatmul_bias_into(
                    m,
                    k,
                    n,
                    &xq,
                    &xscale,
                    qw.values(),
                    qw.scales(),
                    b,
                    act,
                    &mut got,
                );
                qmatmul_into_reference(
                    m,
                    k,
                    n,
                    &xq,
                    &xscale,
                    qw.values(),
                    qw.scales(),
                    b,
                    act,
                    &mut want,
                );
                for (i, (g, e)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(
                        g.to_bits(),
                        e.to_bits(),
                        "case {case} ({m}x{k}x{n}, {act:?}, bias={}) diverged at {i}: \
                         {g} vs {e}",
                        b.is_some()
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// (b) round-trip error ≤ scale/2; single-channel scales exact
// ---------------------------------------------------------------------------

#[test]
fn int8_roundtrip_within_half_scale_and_single_channel_scale_exact() {
    let mut rng = Pcg64::seeded(42);
    for _ in 0..25 {
        let k = 1 + rng.below(40);
        let n = 1 + rng.below(40);
        let w = rand_vec(&mut rng, k * n, 4.0);
        let qw = QuantizedMatrix::from_f32(k, n, &w);
        let deq = qw.dequantize();
        for j in 0..n {
            let s = qw.scales()[j];
            for p in 0..k {
                let err = (w[p * n + j] - deq[p * n + j]).abs();
                assert!(
                    err <= s * 0.5 + 1e-7,
                    "({k}x{n}) col {j}: |{}-{}|={err} > scale/2={}",
                    w[p * n + j],
                    deq[p * n + j],
                    s * 0.5
                );
            }
        }
    }
    // Single-channel input: the per-channel scale is exactly maxabs/127
    // (same f32 division quant_scale performs, no reordering slack).
    let col = vec![0.5f32, -3.25, 1.75, 0.125];
    let qw = QuantizedMatrix::from_f32(4, 1, &col);
    assert_eq!(qw.scales().len(), 1);
    assert_eq!(qw.scales()[0].to_bits(), quant_scale(3.25).to_bits());
    assert_eq!(qw.scales()[0].to_bits(), (3.25f32 / 127.0).to_bits());
}

// ---------------------------------------------------------------------------
// (c) binary popcount matvec ≡ f32 matvec on ±1 matrices, exactly
// ---------------------------------------------------------------------------

#[test]
fn binary_popcount_matvec_exact_on_pm1_matrices() {
    let mut rng = Pcg64::seeded(43);
    for case in 0..25 {
        let k = 1 + rng.below(150); // crosses the 64-bit word boundary
        let n = 1 + rng.below(20);
        let rows = 1 + rng.below(3);
        let sign = |rng: &mut Pcg64| if rng.below(2) == 0 { 1.0f32 } else { -1.0 };
        let w: Vec<f32> = (0..k * n).map(|_| sign(&mut rng)).collect();
        let x: Vec<f32> = (0..rows * k).map(|_| sign(&mut rng)).collect();
        let bias = rand_vec(&mut rng, n, 0.5);
        let bm = BinaryMatrix::from_f32(k, n, &w);
        // ±1 columns: sumabs/k scale is exactly 1, so dequant is exact.
        assert!(bm.scales().iter().all(|&s| s == 1.0), "case {case}: scales");
        for b in [None, Some(bias.as_slice())] {
            let mut got = vec![0.0f32; rows * n];
            bm.apply(rows, &x, b, Activation::Relu, &mut got);
            for i in 0..rows {
                for j in 0..n {
                    // ±1 dot products are small integers — exact in f32 —
                    // and both sides then run the identical relu/bias math.
                    let dot: i32 = (0..k)
                        .map(|p| (x[i * k + p] * w[p * n + j]) as i32)
                        .sum();
                    let mut want = dot as f32 + b.map_or(0.0, |bb| bb[j]);
                    want = want.max(0.0);
                    assert_eq!(
                        got[i * n + j].to_bits(),
                        want.to_bits(),
                        "case {case} ({rows}x{k}x{n}) at ({i},{j}): {} vs {want}",
                        got[i * n + j]
                    );
                }
            }
        }
    }
    // Zero / empty rows binarize with the unit-scale convention.
    let mut bits = Vec::new();
    assert_eq!(binarize_row_into(&[0.0, 0.0, 0.0], &mut bits), 1.0);
    assert_eq!(binarize_row_into(&[], &mut bits), 1.0);
}

// ---------------------------------------------------------------------------
// (d) end-to-end: int8 LED decode vs f32 — logit bound + greedy agreement
// ---------------------------------------------------------------------------

/// Constants calibrated offline with a bit-exact model of this pipeline:
/// 20 seeded configs at these dims agree on 19/20 greedy streams (seed 17
/// diverges on a ~1e-2 logit margin). The assertion allows one more flip
/// (≥ 18) for cross-platform libm (tanh in GELU) variation.
const E2E_CFG: TextModelCfg = TextModelCfg {
    vocab: 12,
    seq: 12,
    d: 48,
    heads: 4,
    layers: 1,
    ff: 96,
    classes: 12,
};
const E2E_SEEDS: u64 = 20;
const E2E_PROMPT_LEN: usize = 4;
const E2E_NEW_TOKENS: usize = 3;
const E2E_MIN_MATCHES: usize = 18;

#[test]
fn int8_led_decode_stays_within_logit_bound_and_greedy_agreement_floor() {
    let backend = NativeBackend::new();
    let greedy = SamplingCfg::greedy();
    let mut matches = 0usize;
    let mut divergences = Vec::new();
    for seed in 0..E2E_SEEDS {
        let mut params = init_text_params(&E2E_CFG, seed);
        auto_fact(
            &mut params,
            &AutoFactConfig {
                rank: Rank::Ratio(0.5),
                solver: Solver::Random,
                num_iter: 0,
                submodules: None,
                precision: WeightPrecision::F32,
            },
        )
        .unwrap();
        let mut graph = synth_fwd_graph("lm", "led_r50", 1, &params).unwrap();
        // The calibrated constants use 4 heads; synth pins the lm default.
        graph.config.insert("heads".to_string(), E2E_CFG.heads);
        let mut prng = Pcg64::new(seed, 11);
        let prompt: Vec<i32> =
            (0..E2E_PROMPT_LEN).map(|_| prng.below(E2E_CFG.vocab) as i32).collect();

        let (store, report) = quantize_led_params(&params, WeightPrecision::Int8).unwrap();
        let bound = report
            .logit_bound
            .expect("LM-shaped checkpoint must yield a propagated logit bound");
        assert!(bound.is_finite() && bound > 0.0, "seed {seed}: bound {bound}");
        let store = Arc::new(store);

        // Prefill logits: |int8 − f32| must stay within the derived bound
        // at every vocab position (the bound is a loose outer envelope —
        // this pins soundness, not tightness).
        let mut s_f32 = DecodeSession::new(&graph, &params).unwrap();
        let mut s_i8 = DecodeSession::with_quant_store(&graph, &params, store.clone()).unwrap();
        let l_f32 = backend.run_decode_step(&graph, &params, &mut s_f32, &prompt).unwrap();
        let l_i8 = backend.run_decode_step(&graph, &params, &mut s_i8, &prompt).unwrap();
        let max_diff = l_f32
            .as_f32()
            .unwrap()
            .iter()
            .zip(l_i8.as_f32().unwrap())
            .map(|(a, b)| (a - b).abs() as f64)
            .fold(0.0f64, f64::max);
        assert!(
            max_diff <= bound,
            "seed {seed}: max |Δlogit| {max_diff:.6} exceeds derived bound {bound:.6}"
        );

        // Greedy streams from fresh sessions.
        let mut s_f32 = DecodeSession::new(&graph, &params).unwrap();
        let out_f32 = generate_with_session(
            &backend, &graph, &params, &mut s_f32, &prompt, E2E_NEW_TOKENS, &greedy, |_, _| {},
        )
        .unwrap();
        let mut s_i8 = DecodeSession::with_quant_store(&graph, &params, store).unwrap();
        assert_eq!(s_i8.precision(), WeightPrecision::Int8);
        let out_i8 = generate_with_session(
            &backend, &graph, &params, &mut s_i8, &prompt, E2E_NEW_TOKENS, &greedy, |_, _| {},
        )
        .unwrap();
        if out_f32.tokens == out_i8.tokens {
            matches += 1;
        } else {
            divergences.push((seed, out_f32.tokens.clone(), out_i8.tokens.clone()));
        }
    }
    for (seed, f, q) in &divergences {
        println!("greedy divergence at seed {seed}: f32={f:?} int8={q:?}");
    }
    println!("greedy agreement: {matches}/{E2E_SEEDS} seeded configs");
    assert!(
        matches >= E2E_MIN_MATCHES,
        "only {matches}/{E2E_SEEDS} greedy streams matched (floor {E2E_MIN_MATCHES}); \
         divergent seeds: {:?}",
        divergences.iter().map(|(s, _, _)| *s).collect::<Vec<_>>()
    );
}
