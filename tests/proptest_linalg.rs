//! Randomized property tests over the linalg substrate (in-tree generator;
//! see proptest_coordinator.rs for the methodology note).

use greenformer::factorize::{rank_for, Solver, MIN_RANK, RANK_MULTIPLE};
use greenformer::linalg::{
    factors_from_svd, jacobi_svd, matmul_bias_into, matmul_into, matmul_into_reference,
    randomized_svd, snmf_factorize, svd_factorize, thin_qr, Activation, Matrix,
};
use greenformer::util::Pcg64;

fn rand_matrix(rng: &mut Pcg64, max_dim: usize) -> Matrix {
    let m = 2 + rng.below(max_dim - 1);
    let n = 2 + rng.below(max_dim - 1);
    Matrix::randn(m, n, 1.0, rng)
}

#[test]
fn svd_truncation_matches_eckart_young_everywhere() {
    let mut rng = Pcg64::seeded(1);
    for case in 0..40 {
        let a = rand_matrix(&mut rng, 40);
        let svd = jacobi_svd(&a);
        let k = svd.s.len();
        let r = 1 + rng.below(k);
        let (fa, fb) = factors_from_svd(&svd, r);
        let err2 = {
            let d = a.sub(&fa.matmul(&fb)).fro_norm();
            d * d
        };
        let tail2: f64 = svd.s[r..].iter().map(|&s| (s as f64) * (s as f64)).sum();
        assert!(
            (err2 - tail2).abs() <= 1e-3 * (1.0 + tail2),
            "case {case}: err2={err2} tail2={tail2} ({}x{}, r={r})",
            a.rows,
            a.cols
        );
    }
}

#[test]
fn svd_singular_values_match_gram_trace() {
    // sum sigma_i^2 == ||A||_F^2 (trace identity), any shape.
    let mut rng = Pcg64::seeded(2);
    for _ in 0..40 {
        let a = rand_matrix(&mut rng, 32);
        let svd = jacobi_svd(&a);
        let sum2: f64 = svd.s.iter().map(|&s| (s as f64) * (s as f64)).sum();
        let fro2 = a.fro_norm().powi(2);
        assert!((sum2 - fro2).abs() < 1e-3 * (1.0 + fro2), "{sum2} vs {fro2}");
    }
}

#[test]
fn qr_reconstruction_and_orthogonality_random_shapes() {
    let mut rng = Pcg64::seeded(3);
    for _ in 0..30 {
        let n = 1 + rng.below(24);
        let m = n + rng.below(40);
        let a = Matrix::randn(m, n, 1.0, &mut rng);
        let (q, r) = thin_qr(&a);
        let qr = q.matmul(&r);
        for (x, y) in qr.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 2e-3 * (1.0 + y.abs()));
        }
        let qtq = q.matmul_tn(&q);
        for i in 0..n {
            for j in 0..n {
                let want = (i == j) as u8 as f32;
                assert!((qtq.at(i, j) - want).abs() < 2e-3);
            }
        }
    }
}

#[test]
fn rsvd_error_bounded_by_oversampled_optimum() {
    let mut rng = Pcg64::seeded(4);
    for _ in 0..10 {
        let m = 40 + rng.below(60);
        let n = 40 + rng.below(60);
        let a = Matrix::randn(m, n, 1.0, &mut rng);
        let r = 4 + rng.below(12);
        let exact = jacobi_svd(&a);
        let tail2: f64 = exact.s[r..].iter().map(|&s| (s as f64) * (s as f64)).sum();
        let approx = randomized_svd(&a, r, 10, 2);
        let (fa, fb) = factors_from_svd(&approx, r);
        let err2 = {
            let d = a.sub(&fa.matmul(&fb)).fro_norm();
            d * d
        };
        assert!(err2 <= tail2 * 1.10 + 1e-6, "err2={err2} optimal={tail2}");
    }
}

#[test]
fn snmf_invariants_random_shapes() {
    let mut rng = Pcg64::seeded(5);
    for case in 0..15 {
        let m = 6 + rng.below(24);
        let n = 6 + rng.below(24);
        let w = Matrix::randn(m, n, 1.0, &mut rng);
        let r = 2 + rng.below(m.min(n) / 2);
        let (a, b) = snmf_factorize(&w, r, 25, case);
        assert_eq!((a.rows, a.cols), (m, r));
        assert_eq!((b.rows, b.cols), (r, n));
        assert!(b.data.iter().all(|&x| x >= 0.0), "case {case}: B must be >= 0");
        assert!(a.data.iter().all(|x| x.is_finite()));
        let rel = w.sub(&a.matmul(&b)).fro_norm() / w.fro_norm();
        assert!(rel < 1.05, "case {case}: rel={rel} (should approximate)");
    }
}

#[test]
fn all_solvers_shapes_and_determinism() {
    let mut rng = Pcg64::seeded(6);
    for case in 0..20 {
        let w = rand_matrix(&mut rng, 30);
        let r = 1 + rng.below(w.rows.min(w.cols));
        for solver in [Solver::Random, Solver::Svd, Solver::Snmf] {
            let (a1, b1) = solver.factorize(&w, r, 8, case);
            let (a2, b2) = solver.factorize(&w, r, 8, case);
            assert_eq!((a1.rows, a1.cols), (w.rows, r));
            assert_eq!((b1.rows, b1.cols), (r, w.cols));
            assert_eq!(a1.data, a2.data, "{solver} must be deterministic");
            assert_eq!(b1.data, b2.data);
        }
    }
}

#[test]
fn rank_policy_invariants_random_inputs() {
    let mut rng = Pcg64::seeded(7);
    for _ in 0..2000 {
        let m = 1 + rng.below(5000);
        let n = 1 + rng.below(5000);
        let ratio = rng.next_f64() * 0.98 + 0.01;
        if let Some(r) = rank_for(m, n, ratio) {
            assert!(r * (m + n) < m * n, "gate violated: ({m},{n},{ratio})->{r}");
            assert!(r >= MIN_RANK);
            assert!(r % RANK_MULTIPLE == 0);
        }
    }
}

#[test]
fn svd_factorize_randomized_path_consistent_with_exact() {
    // The should_randomize() switch must not change results materially.
    let mut rng = Pcg64::seeded(8);
    let a = Matrix::randn(200, 180, 1.0, &mut rng); // triggers rSVD path
    let r = 16;
    let (fa, fb) = svd_factorize(&a, r);
    let exact = jacobi_svd(&a);
    let tail2: f64 = exact.s[r..].iter().map(|&s| (s as f64) * (s as f64)).sum();
    let err2 = {
        let d = a.sub(&fa.matmul(&fb)).fro_norm();
        d * d
    };
    assert!(err2 <= tail2 * 1.05, "err2={err2} tail2={tail2}");
}

// ---------------------------------------------------------------------------
// PR-5 kernel layer: packed GEMM / GEMV / fused epilogues vs the reference
// serial kernel. Equality is asserted BITWISE: every dispatch path keeps the
// same ascending-k single-accumulator chain per output element, so the pool
// split, the packing, and the epilogue fusion must not change even one ulp.
// ---------------------------------------------------------------------------

fn randv(rng: &mut Pcg64, len: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; len];
    rng.fill_normal(&mut v, 1.0);
    v
}

fn assert_bits_eq(tag: &str, a: &[f32], b: &[f32]) {
    assert_eq!(a.len(), b.len(), "{tag}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: elem {i}: {x} vs {y}");
    }
}

#[test]
fn gemm_bitwise_parity_adversarial_shapes() {
    let mut rng = Pcg64::seeded(20);
    // m=1 GEMV (serial and column-split parallel), k=0, single tile,
    // non-divisible MR/NR/KC remainders, and sizes crossing both the packed
    // and the pool-parallel dispatch thresholds.
    let shapes: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (1, 7, 9),
        (1, 300, 500),
        (1, 512, 768),
        (5, 0, 7),
        (8, 8, 8),
        (3, 1, 2),
        (13, 29, 31),
        (17, 257, 63),
        (64, 64, 64),
        (100, 300, 200),
        (96, 130, 120),
        (257, 129, 65),
    ];
    for &(m, k, n) in shapes {
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        // Random initial out contents pin the += accumulate semantics.
        let init = randv(&mut rng, m * n);
        let mut got = init.clone();
        let mut want = init;
        matmul_into(m, k, n, &a, &b, &mut got);
        matmul_into_reference(m, k, n, &a, &b, &mut want);
        assert_bits_eq(&format!("{m}x{k}x{n}"), &got, &want);
    }
}

#[test]
fn gemm_pool_parallel_equals_serial_reference() {
    // Big enough that the row-sharded pool path definitely engages (when
    // the pool is free; a busy pool falls back serially, which must be —
    // and is — indistinguishable). Repeat to catch scheduling variance.
    let mut rng = Pcg64::seeded(21);
    let (m, k, n) = (160, 200, 192);
    let a = randv(&mut rng, m * k);
    let b = randv(&mut rng, k * n);
    let mut want = vec![0.0f32; m * n];
    matmul_into_reference(m, k, n, &a, &b, &mut want);
    for round in 0..5 {
        let mut got = vec![0.0f32; m * n];
        matmul_into(m, k, n, &a, &b, &mut got);
        assert_bits_eq(&format!("round {round}"), &got, &want);
    }
}

#[test]
fn fused_epilogue_bitwise_equals_unfused_passes() {
    use greenformer::linalg::gemm::{gelu_slice, relu_slice};
    let mut rng = Pcg64::seeded(22);
    // (1, 512, 768) crosses the GEMV parallel threshold, so the fused
    // epilogue's per-shard bias slicing is exercised on the pooled path too.
    let shapes =
        [(1usize, 64usize, 96usize), (1, 512, 768), (7, 33, 65), (80, 200, 160), (2, 0, 5)];
    for &(m, k, n) in &shapes {
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let bias = randv(&mut rng, n);
        for act in [Activation::None, Activation::Gelu, Activation::Relu] {
            let mut fused = vec![0.0f32; m * n];
            matmul_bias_into(m, k, n, &a, &b, Some(&bias), act, &mut fused);
            let mut plain = vec![0.0f32; m * n];
            matmul_into(m, k, n, &a, &b, &mut plain);
            for row in plain.chunks_exact_mut(n) {
                for (v, &bv) in row.iter_mut().zip(&bias) {
                    *v += bv;
                }
                match act {
                    Activation::None => {}
                    Activation::Gelu => gelu_slice(row),
                    Activation::Relu => relu_slice(row),
                }
            }
            assert_bits_eq(&format!("{m}x{k}x{n} {act:?}"), &fused, &plain);
        }
    }
}

#[test]
fn matmul_tn_nt_match_f64_naive_at_parallel_sizes() {
    // tn/nt now route through the packed parallel kernels; check against an
    // independent f64-accumulated oracle at sizes that engage them.
    let mut rng = Pcg64::seeded(23);
    let a = Matrix::randn(150, 90, 1.0, &mut rng);
    let b = Matrix::randn(150, 110, 1.0, &mut rng);
    let tn = a.matmul_tn(&b);
    for i in 0..90 {
        for j in 0..110 {
            let mut acc = 0.0f64;
            for p in 0..150 {
                acc += a.at(p, i) as f64 * b.at(p, j) as f64;
            }
            let got = tn.at(i, j);
            assert!((got as f64 - acc).abs() < 1e-3 * (1.0 + acc.abs()), "tn {i},{j}");
        }
    }
    let c = Matrix::randn(120, 90, 1.0, &mut rng);
    let nt = a.matmul_nt(&c);
    for i in 0..150 {
        for j in 0..120 {
            let mut acc = 0.0f64;
            for p in 0..90 {
                acc += a.at(i, p) as f64 * c.at(j, p) as f64;
            }
            let got = nt.at(i, j);
            assert!((got as f64 - acc).abs() < 1e-3 * (1.0 + acc.abs()), "nt {i},{j}");
        }
    }
}

#[test]
fn gemm_concurrent_callers_stay_bitwise_deterministic() {
    // Many threads hammering the kernels at once: whoever wins the pool
    // runs sharded, the rest fall back serially — results must be
    // identical either way.
    let mut rng = Pcg64::seeded(24);
    let (m, k, n) = (96, 128, 112);
    let a = randv(&mut rng, m * k);
    let b = randv(&mut rng, k * n);
    let mut want = vec![0.0f32; m * n];
    matmul_into_reference(m, k, n, &a, &b, &mut want);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..6)
            .map(|_| {
                scope.spawn(|| {
                    for _ in 0..4 {
                        let mut got = vec![0.0f32; m * n];
                        matmul_into(m, k, n, &a, &b, &mut got);
                        for (x, y) in got.iter().zip(&want) {
                            assert_eq!(x.to_bits(), y.to_bits());
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
}

#[test]
fn gemm_associativity_of_led_product() {
    // (x a) b == x (a b) within f32 tolerance — the fusion the LED kernel
    // relies on.
    let mut rng = Pcg64::seeded(9);
    for _ in 0..20 {
        let x = Matrix::randn(8 + rng.below(24), 8 + rng.below(24), 1.0, &mut rng);
        let r = 1 + rng.below(8);
        let a = Matrix::randn(x.cols, r, 1.0, &mut rng);
        let b = Matrix::randn(r, 6 + rng.below(20), 1.0, &mut rng);
        let left = x.matmul(&a).matmul(&b);
        let right = x.matmul(&a.matmul(&b));
        for (u, v) in left.data.iter().zip(&right.data) {
            assert!((u - v).abs() < 1e-3 * (1.0 + v.abs()));
        }
    }
}
