//! Randomized property tests over the linalg substrate (in-tree generator;
//! see proptest_coordinator.rs for the methodology note).

use greenformer::factorize::{rank_for, Solver, MIN_RANK, RANK_MULTIPLE};
use greenformer::linalg::{
    factors_from_svd, jacobi_svd, randomized_svd, snmf_factorize, svd_factorize, thin_qr, Matrix,
};
use greenformer::util::Pcg64;

fn rand_matrix(rng: &mut Pcg64, max_dim: usize) -> Matrix {
    let m = 2 + rng.below(max_dim - 1);
    let n = 2 + rng.below(max_dim - 1);
    Matrix::randn(m, n, 1.0, rng)
}

#[test]
fn svd_truncation_matches_eckart_young_everywhere() {
    let mut rng = Pcg64::seeded(1);
    for case in 0..40 {
        let a = rand_matrix(&mut rng, 40);
        let svd = jacobi_svd(&a);
        let k = svd.s.len();
        let r = 1 + rng.below(k);
        let (fa, fb) = factors_from_svd(&svd, r);
        let err2 = {
            let d = a.sub(&fa.matmul(&fb)).fro_norm();
            d * d
        };
        let tail2: f64 = svd.s[r..].iter().map(|&s| (s as f64) * (s as f64)).sum();
        assert!(
            (err2 - tail2).abs() <= 1e-3 * (1.0 + tail2),
            "case {case}: err2={err2} tail2={tail2} ({}x{}, r={r})",
            a.rows,
            a.cols
        );
    }
}

#[test]
fn svd_singular_values_match_gram_trace() {
    // sum sigma_i^2 == ||A||_F^2 (trace identity), any shape.
    let mut rng = Pcg64::seeded(2);
    for _ in 0..40 {
        let a = rand_matrix(&mut rng, 32);
        let svd = jacobi_svd(&a);
        let sum2: f64 = svd.s.iter().map(|&s| (s as f64) * (s as f64)).sum();
        let fro2 = a.fro_norm().powi(2);
        assert!((sum2 - fro2).abs() < 1e-3 * (1.0 + fro2), "{sum2} vs {fro2}");
    }
}

#[test]
fn qr_reconstruction_and_orthogonality_random_shapes() {
    let mut rng = Pcg64::seeded(3);
    for _ in 0..30 {
        let n = 1 + rng.below(24);
        let m = n + rng.below(40);
        let a = Matrix::randn(m, n, 1.0, &mut rng);
        let (q, r) = thin_qr(&a);
        let qr = q.matmul(&r);
        for (x, y) in qr.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 2e-3 * (1.0 + y.abs()));
        }
        let qtq = q.matmul_tn(&q);
        for i in 0..n {
            for j in 0..n {
                let want = (i == j) as u8 as f32;
                assert!((qtq.at(i, j) - want).abs() < 2e-3);
            }
        }
    }
}

#[test]
fn rsvd_error_bounded_by_oversampled_optimum() {
    let mut rng = Pcg64::seeded(4);
    for _ in 0..10 {
        let m = 40 + rng.below(60);
        let n = 40 + rng.below(60);
        let a = Matrix::randn(m, n, 1.0, &mut rng);
        let r = 4 + rng.below(12);
        let exact = jacobi_svd(&a);
        let tail2: f64 = exact.s[r..].iter().map(|&s| (s as f64) * (s as f64)).sum();
        let approx = randomized_svd(&a, r, 10, 2);
        let (fa, fb) = factors_from_svd(&approx, r);
        let err2 = {
            let d = a.sub(&fa.matmul(&fb)).fro_norm();
            d * d
        };
        assert!(err2 <= tail2 * 1.10 + 1e-6, "err2={err2} optimal={tail2}");
    }
}

#[test]
fn snmf_invariants_random_shapes() {
    let mut rng = Pcg64::seeded(5);
    for case in 0..15 {
        let m = 6 + rng.below(24);
        let n = 6 + rng.below(24);
        let w = Matrix::randn(m, n, 1.0, &mut rng);
        let r = 2 + rng.below(m.min(n) / 2);
        let (a, b) = snmf_factorize(&w, r, 25, case);
        assert_eq!((a.rows, a.cols), (m, r));
        assert_eq!((b.rows, b.cols), (r, n));
        assert!(b.data.iter().all(|&x| x >= 0.0), "case {case}: B must be >= 0");
        assert!(a.data.iter().all(|x| x.is_finite()));
        let rel = w.sub(&a.matmul(&b)).fro_norm() / w.fro_norm();
        assert!(rel < 1.05, "case {case}: rel={rel} (should approximate)");
    }
}

#[test]
fn all_solvers_shapes_and_determinism() {
    let mut rng = Pcg64::seeded(6);
    for case in 0..20 {
        let w = rand_matrix(&mut rng, 30);
        let r = 1 + rng.below(w.rows.min(w.cols));
        for solver in [Solver::Random, Solver::Svd, Solver::Snmf] {
            let (a1, b1) = solver.factorize(&w, r, 8, case);
            let (a2, b2) = solver.factorize(&w, r, 8, case);
            assert_eq!((a1.rows, a1.cols), (w.rows, r));
            assert_eq!((b1.rows, b1.cols), (r, w.cols));
            assert_eq!(a1.data, a2.data, "{solver} must be deterministic");
            assert_eq!(b1.data, b2.data);
        }
    }
}

#[test]
fn rank_policy_invariants_random_inputs() {
    let mut rng = Pcg64::seeded(7);
    for _ in 0..2000 {
        let m = 1 + rng.below(5000);
        let n = 1 + rng.below(5000);
        let ratio = rng.next_f64() * 0.98 + 0.01;
        if let Some(r) = rank_for(m, n, ratio) {
            assert!(r * (m + n) < m * n, "gate violated: ({m},{n},{ratio})->{r}");
            assert!(r >= MIN_RANK);
            assert!(r % RANK_MULTIPLE == 0);
        }
    }
}

#[test]
fn svd_factorize_randomized_path_consistent_with_exact() {
    // The should_randomize() switch must not change results materially.
    let mut rng = Pcg64::seeded(8);
    let a = Matrix::randn(200, 180, 1.0, &mut rng); // triggers rSVD path
    let r = 16;
    let (fa, fb) = svd_factorize(&a, r);
    let exact = jacobi_svd(&a);
    let tail2: f64 = exact.s[r..].iter().map(|&s| (s as f64) * (s as f64)).sum();
    let err2 = {
        let d = a.sub(&fa.matmul(&fb)).fro_norm();
        d * d
    };
    assert!(err2 <= tail2 * 1.05, "err2={err2} tail2={tail2}");
}

#[test]
fn gemm_associativity_of_led_product() {
    // (x a) b == x (a b) within f32 tolerance — the fusion the LED kernel
    // relies on.
    let mut rng = Pcg64::seeded(9);
    for _ in 0..20 {
        let x = Matrix::randn(8 + rng.below(24), 8 + rng.below(24), 1.0, &mut rng);
        let r = 1 + rng.below(8);
        let a = Matrix::randn(x.cols, r, 1.0, &mut rng);
        let b = Matrix::randn(r, 6 + rng.below(20), 1.0, &mut rng);
        let left = x.matmul(&a).matmul(&b);
        let right = x.matmul(&a.matmul(&b));
        for (u, v) in left.data.iter().zip(&right.data) {
            assert!((u - v).abs() < 1e-3 * (1.0 + v.abs()));
        }
    }
}
