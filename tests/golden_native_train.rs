//! Golden-value regression for native training: the first seed-0 loss
//! curves of one text and one image task, pinned.
//!
//! Any change to the forward pass, the backward pass, the Adam step, the
//! parameter init, or the data pipeline shifts these losses and must fail
//! here first (and update the constants deliberately).
//!
//! Derivation: python/tools/derive_native_train_golden.py — an independent
//! numpy/float32 reimplementation with the same accumulation order as the
//! Rust kernels. The script first re-derives the PR-2 `tests/golden_data.rs`
//! constants from its own PCG64 streams (validating the entire random-stream
//! plumbing) before emitting these numbers. Remaining cross-implementation
//! noise is transcendental-libm ulps; an injected-noise experiment bounds
//! its effect on these losses at ~1e-6, so the 2e-3 tolerance is ~1000x
//! slack while still catching any real regression (a wrong bias correction,
//! a dropped gradient term, or an optimizer reorder moves the curve by
//! >5e-2 within a few steps).

use greenformer::backend::native::{
    init_image_params, init_text_params, ImageModelCfg, TextModelCfg,
};
use greenformer::backend::NativeBackend;
use greenformer::data::image::BlobsTask;
use greenformer::data::text::PolarityTask;
use greenformer::train::Trainer;

const BACKEND: NativeBackend = NativeBackend;
const TOL: f32 = 2e-3;

#[rustfmt::skip]
const TEXT_LOSSES: [f32; 10] = [
    1.390283, 0.941647, 0.845105, 0.856093, 0.642654,
    0.718000, 0.674257, 0.746622, 0.736440, 0.640565,
];

#[rustfmt::skip]
const IMAGE_LOSSES: [f32; 6] = [
    1.319456, 1.412409, 1.495669, 1.316948, 1.378237, 1.407689,
];

fn assert_curve(got: &[f32], want: &[f32], tag: &str) {
    assert_eq!(got.len(), want.len(), "{tag}: step count");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() < TOL,
            "{tag} step {}: loss {g:.6} vs pinned {w:.6}",
            i + 1
        );
    }
}

#[test]
fn text_training_losses_pinned() {
    let cfg = TextModelCfg {
        vocab: 512,
        seq: 64,
        d: 32,
        heads: 4,
        layers: 1,
        ff: 64,
        classes: 4,
    };
    let params = init_text_params(&cfg, 1);
    let mut trainer = Trainer::native(&BACKEND, "text", "dense", 8, params).unwrap();
    let ds = PolarityTask::new(64, 0);
    trainer.train_classifier(&ds, TEXT_LOSSES.len(), None, |_| {}).unwrap();
    let got: Vec<f32> = trainer.history.iter().map(|l| l.loss).collect();
    assert_curve(&got, &TEXT_LOSSES, "text/polarity");
    // The pinned curve itself encodes learning: by step 10 the model is
    // well below the 4-way-uniform ln(4) and the binary-uniform ln(2).
    assert!(got[9] < 0.693);
}

#[test]
fn image_training_losses_pinned() {
    let cfg = ImageModelCfg {
        hw: 28,
        ch: 1,
        classes: 4,
        c1: 4,
        c2: 8,
        fc: 16,
    };
    let params = init_image_params(&cfg, 2);
    let mut trainer = Trainer::native(&BACKEND, "image", "dense", 4, params).unwrap();
    let ds = BlobsTask::new(0);
    trainer
        .train_classifier(&ds, IMAGE_LOSSES.len(), Some((28, 28, 1)), |_| {})
        .unwrap();
    let got: Vec<f32> = trainer.history.iter().map(|l| l.loss).collect();
    assert_curve(&got, &IMAGE_LOSSES, "image/blobs");
}

#[test]
fn training_is_deterministic_across_runs() {
    // Same init + data => bit-identical losses, regardless of thread count
    // (matmul_into accumulates in a fixed k-order per output element).
    let cfg = TextModelCfg {
        vocab: 512,
        seq: 64,
        d: 32,
        heads: 4,
        layers: 1,
        ff: 64,
        classes: 4,
    };
    let run = || {
        let params = init_text_params(&cfg, 1);
        let mut t = Trainer::native(&BACKEND, "text", "dense", 8, params).unwrap();
        let ds = PolarityTask::new(64, 0);
        t.train_classifier(&ds, 3, None, |_| {}).unwrap();
        t.history.iter().map(|l| l.loss).collect::<Vec<f32>>()
    };
    assert_eq!(run(), run());
}
