//! Golden-value regression for the deterministic data generators.
//!
//! Pins the first Train example of each of the 5 synthetic tasks at seed 0:
//! full token sequences + labels for the text tasks, and label + strided
//! pixel probes + total mass for the image tasks. Every downstream accuracy
//! number in the experiments is a function of these streams, so a PCG64 or
//! data-pipeline refactor that silently shifts them must fail here first
//! (and update these constants deliberately).
//!
//! Derivation: the values were cross-checked against an independent PCG64
//! implementation (numpy's, same XSL-RR 128/64 output function). Token
//! values are exact; pixel probes carry a small tolerance for libm ulp
//! differences in the Box–Muller/Gaussian path.

use greenformer::data::image::{BlobsTask, ShapesTask, HW};
use greenformer::data::text::{MatchingTask, PolarityTask, TopicTask};
use greenformer::data::{Dataset, Split};

const SEQ: usize = 64;

#[rustfmt::skip]
const POLARITY_TOKENS: [i32; 64] = [
    1, 111, 66, 380, 475, 64, 68, 200, 402, 57, 449, 389, 219, 413, 361, 108,
    173, 142, 45, 337, 420, 252, 395, 125, 248, 178, 490, 56, 122, 157, 18, 178,
    413, 305, 310, 403, 185, 152, 321, 472, 480, 328, 158, 208, 117, 323, 510, 413,
    490, 271, 90, 137, 329, 253, 499, 189, 295, 125, 190, 54, 432, 337, 48, 507,
];

#[rustfmt::skip]
const TOPIC_TOKENS: [i32; 64] = [
    1, 396, 490, 355, 238, 210, 382, 416, 312, 241, 119, 254, 476, 454, 442, 450,
    245, 425, 389, 94, 234, 145, 138, 309, 316, 453, 328, 341, 358, 507, 285, 309,
    229, 496, 336, 378, 433, 129, 505, 210, 344, 370, 124, 330, 359, 365, 351, 235,
    386, 413, 208, 345, 484, 302, 421, 430, 373, 123, 300, 366, 293, 271, 328, 428,
];

#[rustfmt::skip]
const MATCHING_TOKENS: [i32; 64] = [
    1, 461, 463, 390, 391, 312, 469, 324, 400, 442, 507, 473, 344, 412, 289, 213,
    262, 422, 342, 301, 326, 333, 395, 349, 375, 435, 496, 479, 359, 464, 424, 475,
    2, 439, 485, 386, 423, 385, 403, 369, 442, 364, 441, 489, 401, 355, 424, 343,
    420, 332, 213, 262, 437, 284, 374, 480, 314, 388, 411, 279, 409, 440, 303, 482,
];

/// Pixel probe positions: every 49th pixel of the 28×28 image.
const PIX_IDX: [usize; 16] = [
    0, 49, 98, 147, 196, 245, 294, 343, 392, 441, 490, 539, 588, 637, 686, 735,
];

#[rustfmt::skip]
const SHAPES_PROBES: [f32; 16] = [
    0.0, 0.126298, 0.0, 0.0566745, 0.0, 0.00977657, 0.0, 0.0513239,
    0.0, 0.0, 0.016975, 0.0970927, 0.0, 0.0, 0.0881016, 0.0,
];
const SHAPES_SUM: f64 = 70.351784;

#[rustfmt::skip]
const BLOBS_PROBES: [f32; 16] = [
    0.057342, 0.0645856, 0.0813607, 0.0247114, 0.0428923, 0.00321283, 0.0, 0.0,
    0.0059928, 0.104664, 0.00801224, 0.0141336, 0.0, 0.893152, 0.0432883, 0.269171,
];
const BLOBS_SUM: f64 = 55.678268;

const PIX_TOL: f32 = 1e-3;
const SUM_TOL: f64 = 0.2;

#[test]
fn polarity_seed0_first_example_pinned() {
    let ex = PolarityTask::new(SEQ, 0).example(Split::Train, 0);
    assert_eq!(ex.label, 0);
    assert_eq!(ex.tokens, POLARITY_TOKENS.to_vec());
}

#[test]
fn topic_seed0_first_example_pinned() {
    let ex = TopicTask::new(SEQ, 0).example(Split::Train, 0);
    assert_eq!(ex.label, 1);
    assert_eq!(ex.tokens, TOPIC_TOKENS.to_vec());
}

#[test]
fn matching_seed0_first_example_pinned() {
    let ex = MatchingTask::new(SEQ, 0).example(Split::Train, 0);
    assert_eq!(ex.label, 0); // ENTAIL: premise pair repeats in the hypothesis
    assert_eq!(ex.tokens, MATCHING_TOKENS.to_vec());
    // Structural cross-check of the pinned stream.
    assert_eq!(ex.tokens[32], 2); // SEP at seq/2
    assert_eq!((ex.tokens[15], ex.tokens[16]), (213, 262)); // premise (s, a)
    assert_eq!((ex.tokens[50], ex.tokens[51]), (213, 262)); // entailed restatement
}

fn check_image(pixels: &[f32], probes: &[f32; 16], sum: f64, tag: &str) {
    assert_eq!(pixels.len(), HW * HW, "{tag}");
    for (&i, &want) in PIX_IDX.iter().zip(probes) {
        let got = pixels[i];
        assert!((got - want).abs() < PIX_TOL, "{tag} pixel {i}: {got} vs {want}");
    }
    let total: f64 = pixels.iter().map(|&p| p as f64).sum();
    assert!((total - sum).abs() < SUM_TOL, "{tag} sum: {total} vs {sum}");
}

#[test]
fn shapes_seed0_first_example_pinned() {
    let ex = ShapesTask::new(0).example(Split::Train, 0);
    assert_eq!(ex.label, 0); // square
    check_image(&ex.pixels, &SHAPES_PROBES, SHAPES_SUM, "shapes");
}

#[test]
fn blobs_seed0_first_example_pinned() {
    let ex = BlobsTask::new(0).example(Split::Train, 0);
    assert_eq!(ex.label, 3); // bump in quadrant (21, 21)
    check_image(&ex.pixels, &BLOBS_PROBES, BLOBS_SUM, "blobs");
    // The quadrant-3 bump dominates: the probe inside it is the brightest.
    let bright = PIX_IDX.iter().map(|&i| ex.pixels[i]).fold(0.0f32, f32::max);
    assert!((bright - 0.893152).abs() < PIX_TOL);
}

// ---------------------------------------------------------------------------
// TT-SVD golden pin
// ---------------------------------------------------------------------------
//
// Seed-0 TT-SVD of a fixed 64x64 weight, cross-derived from the independent
// numpy TT-SVD mirror in `python/tools/derive_tt_golden.py` (LAPACK SVD +
// the same PCG64 stream, permutation, and energy-budget rank rule). Only
// gauge-invariant quantities are pinned — internal ranks, parameter count,
// relative reconstruction error, and probes of the *reconstructed* weight —
// since individual core entries are defined only up to an orthogonal gauge.
//
// The weight is a 4-term Kronecker sum with 0.5^l scales, so the grouped
// unfolding has ~2x singular-value gaps at every candidate rank: the script
// asserts the tau = 0.95 crossing and the spectral gap at the cut are both
// wide before emitting constants, making the pin robust to Jacobi-vs-LAPACK
// float differences.

const TT_GOLDEN_RANKS: &[usize] = &[3];
const TT_GOLDEN_N_PARAMS: usize = 384;
const TT_GOLDEN_RECON_ERR: f64 = 0.0950432;
#[rustfmt::skip]
const TT_GOLDEN_ROW0_PROBES: [f32; 8] = [
    -0.218683, -1.97586, 0.950023, -1.02101, 1.82286, 1.34455, -0.855484, 0.181096,
];

#[test]
fn tt_svd_seed0_pinned() {
    use greenformer::factorize::{tt_svd, TtConfig};
    use greenformer::linalg::Matrix;
    use greenformer::util::Pcg64;

    let mut rng = Pcg64::seeded(0);
    let mut w = Matrix::zeros(64, 64);
    for l in 0..4 {
        let a = Matrix::randn(8, 8, 1.0, &mut rng);
        let b = Matrix::randn(8, 8, 1.0, &mut rng);
        let scale = 0.5f32.powi(l);
        for i1 in 0..8 {
            for i2 in 0..8 {
                for j1 in 0..8 {
                    for j2 in 0..8 {
                        *w.at_mut(i1 * 8 + i2, j1 * 8 + j2) += scale * a.at(i1, j1) * b.at(i2, j2);
                    }
                }
            }
        }
    }

    let cfg = TtConfig { modes: 2, energy: 0.95, max_rank: None };
    let tt = tt_svd(&w, &cfg).expect("tt_svd on 64x64");
    assert_eq!(tt.ranks(), TT_GOLDEN_RANKS, "internal TT ranks");
    assert_eq!(tt.n_params(), TT_GOLDEN_N_PARAMS, "TT parameter count");

    let rec = tt.reconstruct();
    let err = w.sub(&rec).fro_norm() / w.fro_norm();
    assert!(
        (err - TT_GOLDEN_RECON_ERR).abs() < 1e-3,
        "recon error drifted: {err} vs {TT_GOLDEN_RECON_ERR}"
    );
    for (p, (&want, c)) in TT_GOLDEN_ROW0_PROBES.iter().zip((0..64).step_by(8)).enumerate() {
        let got = rec.at(0, c);
        assert!((got - want).abs() < 5e-3, "probe {p} at (0, {c}): {got} vs {want}");
    }
}
