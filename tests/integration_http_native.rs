//! End-to-end boot test of the HTTP front end over the model registry —
//! the suite CI drives against a real socket on a random port: classify,
//! streamed generate, structured rejections, clean shutdown.
//!
//! Hermetic by construction: models are installed in-memory
//! (`install_local`), no artifacts, no network beyond loopback.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use greenformer::backend::native::{init_text_params, TextModelCfg};
use greenformer::backend::SamplingCfg;
use greenformer::coordinator::Tier;
use greenformer::registry::ModelRegistry;
use greenformer::serve_http::{client, HttpConfig, HttpServer};
use greenformer::tensor::ParamStore;

const SEQ: usize = 8;

fn tiny_cfg() -> TextModelCfg {
    TextModelCfg { vocab: 64, seq: SEQ, d: 32, heads: 4, layers: 1, ff: 64, classes: 3 }
}

fn store(seed: u64) -> ParamStore {
    init_text_params(&tiny_cfg(), seed)
}

fn one_variant(seed: u64) -> HashMap<String, ParamStore> {
    let mut m = HashMap::new();
    m.insert("dense".to_string(), store(seed));
    m
}

/// A registry with one classifier (`clf`) and one generator (`gen`) plus a
/// server bound to an ephemeral loopback port.
fn boot() -> (Arc<ModelRegistry>, HttpServer) {
    let registry = Arc::new(ModelRegistry::new());
    registry.install_local("clf", "text", "v1", "dense", one_variant(7), None).unwrap();
    registry.install_local("gen", "lm", "v1", "dense", one_variant(9), None).unwrap();
    let server =
        HttpServer::bind("127.0.0.1:0", registry.clone(), HttpConfig::default()).unwrap();
    (registry, server)
}

const T: Duration = Duration::from_secs(10);

#[test]
fn full_surface_boot_classify_generate_shutdown() {
    let (registry, server) = boot();
    let addr = server.local_addr();

    // -- healthz ------------------------------------------------------------
    let r = client::request(addr, "/v1/healthz", None, T).unwrap();
    assert_eq!(r.status, 200, "{}", r.body_text());
    let v = r.json().unwrap();
    assert_eq!(v.str_or("status", ""), "ok");
    assert_eq!(v.usize_or("models", 0), 2);

    // -- models listing -----------------------------------------------------
    let r = client::request(addr, "/v1/models", None, T).unwrap();
    assert_eq!(r.status, 200);
    let v = r.json().unwrap();
    let models = v.get("models").unwrap().as_arr().unwrap();
    assert_eq!(models.len(), 2);
    let names: Vec<String> = models.iter().map(|m| m.str_or("name", "")).collect();
    assert_eq!(names, vec!["clf".to_string(), "gen".to_string()]);
    assert_eq!(models[0].usize_or("seq", 0), SEQ);

    // -- classify -----------------------------------------------------------
    let tokens: Vec<i32> = (0..SEQ as i32).collect();
    let body = format!(
        "{{\"model\":\"clf\",\"tokens\":{:?},\"tier\":\"quality\"}}",
        tokens
    );
    let r = client::request(addr, "/v1/classify", Some(&body), T).unwrap();
    assert_eq!(r.status, 200, "{}", r.body_text());
    let v = r.json().unwrap();
    assert_eq!(v.str_or("model", ""), "clf");
    assert_eq!(v.str_or("variant", ""), "dense");
    let http_label = v.usize_or("label", usize::MAX);
    assert_eq!(v.get("logits").unwrap().as_arr().unwrap().len(), 3);

    // The HTTP answer must agree with an in-process call on the same model.
    let direct = registry
        .get("clf")
        .unwrap()
        .handle()
        .classify(tokens.clone(), Tier::Quality)
        .unwrap();
    assert_eq!(http_label, direct.label);

    // -- generate (chunked ndjson stream) ------------------------------------
    let body = r#"{"model":"gen","prompt":[1,2,3],"max_new":4}"#;
    let r = client::request(addr, "/v1/generate", Some(body), T).unwrap();
    assert_eq!(r.status, 200, "{}", r.body_text());
    assert_eq!(
        r.headers.get("transfer-encoding").map(String::as_str),
        Some("chunked"),
        "generate must stream"
    );
    let events = r.ndjson().unwrap();
    assert!(events.len() >= 2, "expected token events + done, got {events:?}");
    let done = events.last().unwrap();
    assert_eq!(done.str_or("event", ""), "done");
    assert_eq!(done.str_or("model", ""), "gen");
    let streamed: Vec<i64> = done
        .get("tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|t| t.as_f64().unwrap() as i64)
        .collect();
    assert_eq!(streamed.len(), 4);
    // Every token event must agree with the final summary, in order.
    let per_event: Vec<i64> = events[..events.len() - 1]
        .iter()
        .map(|e| {
            assert_eq!(e.str_or("event", ""), "token");
            e.get("token").unwrap().as_f64().unwrap() as i64
        })
        .collect();
    assert_eq!(per_event, streamed);

    // Greedy decoding through HTTP must be bit-identical to an in-process
    // generate on the same model.
    let direct = registry
        .get("gen")
        .unwrap()
        .handle()
        .generate_collect(vec![1, 2, 3], 4, SamplingCfg::greedy(), Tier::Quality)
        .unwrap();
    let direct_tokens: Vec<i64> = direct.tokens.iter().map(|&t| t as i64).collect();
    assert_eq!(streamed, direct_tokens);

    // -- structured rejections ----------------------------------------------
    // Not JSON at all.
    let r = client::request(addr, "/v1/classify", Some("not json"), T).unwrap();
    assert_eq!(r.status, 400);
    assert_eq!(r.json().unwrap().get("error").unwrap().str_or("code", ""), "bad_request");

    // Unknown field → schema rejection with a JSON path.
    let r = client::request(addr, "/v1/classify", Some(r#"{"tokens":[1],"bogus":1}"#), T).unwrap();
    assert_eq!(r.status, 400);
    let err = r.json().unwrap();
    let e = err.get("error").unwrap();
    assert_eq!(e.str_or("code", ""), "invalid_request");
    assert!(e.str_or("message", "").contains("body.bogus"), "{}", r.body_text());

    // Wrong token count (schema passes, model window check rejects).
    let r = client::request(
        addr,
        "/v1/classify",
        Some(r#"{"model":"clf","tokens":[1,2,3]}"#),
        T,
    )
    .unwrap();
    assert_eq!(r.status, 400);
    assert!(r.body_text().contains("model window"), "{}", r.body_text());

    // Unknown model → 404.
    let r = client::request(
        addr,
        "/v1/classify",
        Some(&format!("{{\"model\":\"nope\",\"tokens\":{tokens:?}}}")),
        T,
    )
    .unwrap();
    assert_eq!(r.status, 404);

    // Family mismatch: classify on the LM → 400.
    let r = client::request(
        addr,
        "/v1/classify",
        Some(&format!("{{\"model\":\"gen\",\"tokens\":{tokens:?}}}")),
        T,
    )
    .unwrap();
    assert_eq!(r.status, 400);
    assert!(r.body_text().contains("family"), "{}", r.body_text());

    // Ambiguous default: two models registered, none named.
    let r = client::request(
        addr,
        "/v1/classify",
        Some(&format!("{{\"tokens\":{tokens:?}}}")),
        T,
    )
    .unwrap();
    assert_eq!(r.status, 400);

    // Method / path errors.
    let raw = client::request_raw(
        addr,
        b"DELETE /v1/classify HTTP/1.1\r\nContent-Length: 0\r\n\r\n",
        T,
    )
    .unwrap();
    assert_eq!(client::parse_response(&raw).unwrap().status, 405);
    let r = client::request(addr, "/v1/nope", None, T).unwrap();
    assert_eq!(r.status, 404);

    // -- metrics + clean shutdown -------------------------------------------
    let r = client::request(addr, "/v1/metrics", None, T).unwrap();
    assert_eq!(r.status, 200);
    let v = r.json().unwrap();
    let http = v.get("http").unwrap();
    let total = http.usize_or("requests", 0);
    let accounted = http.usize_or("ok", 0)
        + http.usize_or("client_errors", 0)
        + http.usize_or("server_errors", 0)
        + http.usize_or("shed", 0);
    assert_eq!(total, accounted, "status classes must reconcile: {}", r.body_text());
    assert!(v.get("models").unwrap().as_arr().unwrap().len() == 2);

    server.shutdown().unwrap();
}

#[test]
fn single_model_registry_needs_no_model_field() {
    let registry = Arc::new(ModelRegistry::new());
    registry.install_local("only", "text", "v1", "dense", one_variant(3), None).unwrap();
    let server = HttpServer::bind("127.0.0.1:0", registry, HttpConfig::default()).unwrap();
    let tokens: Vec<i32> = (0..SEQ as i32).collect();
    let r = client::request(
        server.local_addr(),
        "/v1/classify",
        Some(&format!("{{\"tokens\":{tokens:?}}}")),
        T,
    )
    .unwrap();
    assert_eq!(r.status, 200, "{}", r.body_text());
    assert_eq!(r.json().unwrap().str_or("model", ""), "only");
    server.shutdown().unwrap();
}
