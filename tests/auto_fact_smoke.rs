//! End-to-end smoke test of the paper's one-liner over a purely synthetic,
//! in-memory checkpoint — no GTZ file and no AOT artifacts required, so this
//! runs (and must pass) on a completely fresh checkout.
//!
//! The weights are built exactly rank-8 plus 1% noise, so the SVD solver at
//! `Rank::Ratio(0.25)` (which resolves to rank ≥ 16 for these shapes) must
//! reconstruct them almost losslessly while cutting the parameter count.

use greenformer::factorize::auto_fact::Decision;
use greenformer::factorize::{auto_fact, AutoFactConfig, Rank, Solver};
use greenformer::linalg::Matrix;
use greenformer::model::{classify, LayerKind};
use greenformer::tensor::{Dtype, ParamStore, Tensor};
use greenformer::util::Pcg64;

fn low_rank_noisy(m: usize, n: usize, k: usize, rng: &mut Pcg64) -> Matrix {
    let u = Matrix::randn(m, k, 1.0, rng);
    let v = Matrix::randn(k, n, 1.0, rng);
    let mut w = u.matmul(&v);
    for x in w.data.iter_mut() {
        *x += rng.normal_f32() * 0.01;
    }
    w
}

fn add_linear(
    store: &mut ParamStore,
    originals: &mut Vec<(String, Matrix)>,
    rng: &mut Pcg64,
    prefix: &str,
    m: usize,
    n: usize,
) {
    let w = low_rank_noisy(m, n, 8, rng);
    store.insert(format!("{prefix}/w"), Tensor::from_f32(&[m, n], w.data.clone()));
    originals.push((prefix.to_string(), w));
}

/// A small transformer-shaped checkpoint: three factorizable linears, one
/// gate-rejected linear, an embedding and a layernorm.
fn synthetic_store(rng: &mut Pcg64) -> (ParamStore, Vec<(String, Matrix)>) {
    let mut s = ParamStore::new();
    let mut originals = Vec::new();
    add_linear(&mut s, &mut originals, rng, "block0/attn/q", 128, 128);
    s.insert("block0/attn/q/bias", Tensor::zeros(&[128], Dtype::F32));
    add_linear(&mut s, &mut originals, rng, "block0/fc1", 128, 256);
    add_linear(&mut s, &mut originals, rng, "block0/fc2", 256, 128);
    s.insert("embed/table", Tensor::zeros(&[512, 64], Dtype::F32));
    s.insert("head/w", Tensor::zeros(&[16, 16], Dtype::F32));
    s.insert("ln/g", Tensor::zeros(&[64], Dtype::F32));
    s.insert("ln/bias", Tensor::zeros(&[64], Dtype::F32));
    (s, originals)
}

fn as_matrix(t: &Tensor) -> Matrix {
    let (rows, cols, data) = t.as_matrix_2d().unwrap();
    Matrix::from_vec(rows, cols, data.to_vec())
}

#[test]
fn auto_fact_smoke_shrinks_params_with_bounded_error() {
    let mut rng = Pcg64::seeded(2024);
    let (mut store, originals) = synthetic_store(&mut rng);
    let before = store.n_params();

    let report = auto_fact(
        &mut store,
        &AutoFactConfig {
            rank: Rank::Ratio(0.25),
            solver: Solver::Svd,
            num_iter: 50,
            submodules: None,
            ..Default::default()
        },
    )
    .unwrap();

    // The three big linears factorize; the rest stay put.
    assert_eq!(report.n_factorized(), 3, "{report}");
    assert_eq!(report.params_before, before);
    assert_eq!(report.params_after, store.n_params());
    assert!(store.n_params() < before, "{} -> {}", before, store.n_params());
    assert!(report.compression() < 0.5, "compression {}", report.compression());

    // Per-layer decisions: Eq.-1 gate keeps head/w dense; embedding and
    // layernorm are not applicable.
    let decision = |name: &str| {
        report
            .layers
            .iter()
            .find(|l| l.name == name)
            .unwrap_or_else(|| panic!("no decision for {name}"))
            .decision
            .clone()
    };
    assert_eq!(decision("block0/attn/q"), Decision::Factorized { rank: 16 });
    assert_eq!(decision("block0/fc1"), Decision::Factorized { rank: 16 });
    assert_eq!(decision("head"), Decision::GateRejected);
    assert_eq!(decision("embed"), Decision::NotApplicable);
    assert_eq!(decision("ln"), Decision::NotApplicable);

    // LED shapes replace the dense weights.
    assert!(store.get("block0/attn/q/w").is_none());
    assert_eq!(store.get("block0/attn/q/a").unwrap().shape, vec![128, 16]);
    assert_eq!(store.get("block0/attn/q/b").unwrap().shape, vec![16, 128]);
    assert!(store.get("block0/attn/q/bias").is_some());
    assert!(store.get("head/w").is_some());
    assert!(store.get("embed/table").is_some());

    // Reconstruction error stays bounded: rank-8 + 1% noise truncated at
    // rank 16 must be nearly lossless.
    for (prefix, w) in &originals {
        let a = as_matrix(store.get(&format!("{prefix}/a")).unwrap());
        let b = as_matrix(store.get(&format!("{prefix}/b")).unwrap());
        let rel = w.sub(&a.matmul(&b)).fro_norm() / w.fro_norm();
        assert!(rel < 0.05, "{prefix}: rel recon error {rel}");
    }
    for l in &report.layers {
        if let Decision::Factorized { .. } = l.decision {
            let e = l.recon_error.expect("SVD reports reconstruction error");
            assert!(e < 0.05, "{}: reported error {e}", l.name);
        }
    }

    // The factorized store reclassifies as LED layers, in canonical order.
    let layers = classify(&store);
    let kind = |name: &str| layers.iter().find(|l| l.name == name).unwrap().kind;
    assert_eq!(kind("block0/attn/q"), LayerKind::LedLinear);
    assert_eq!(kind("block0/fc1"), LayerKind::LedLinear);
    assert_eq!(kind("head"), LayerKind::Linear);
    let names = store.names().to_vec();
    let mut sorted = names.clone();
    sorted.sort();
    assert_eq!(names, sorted, "store must stay canonically sorted");
}

#[test]
fn auto_fact_smoke_respects_submodule_filter() {
    let mut rng = Pcg64::seeded(7);
    let (mut store, _) = synthetic_store(&mut rng);

    let report = auto_fact(
        &mut store,
        &AutoFactConfig {
            rank: Rank::Ratio(0.25),
            solver: Solver::Svd,
            num_iter: 50,
            submodules: Some(vec!["fc1".to_string()]),
            ..Default::default()
        },
    )
    .unwrap();

    assert_eq!(report.n_factorized(), 1, "{report}");
    assert!(store.get("block0/fc1/a").is_some());
    assert!(store.get("block0/attn/q/w").is_some(), "filtered layer must stay dense");
    let filtered = report
        .layers
        .iter()
        .filter(|l| l.decision == Decision::Filtered)
        .count();
    assert_eq!(filtered, 3, "q, fc2 and head are filtered out");
}
