//! Registry hot-swap under load, and the fail-closed install gates.
//!
//! The contract: applying a new manifest version swaps the serving slot
//! atomically — sessions that pinned the old `Arc` finish **bit-identical**
//! on the old parameters — while any verification failure (tampered bytes,
//! corrupt payload, missing file) rejects that model without disturbing
//! the version already serving.

use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Barrier};

use greenformer::backend::native::{init_text_params, TextModelCfg};
use greenformer::backend::SamplingCfg;
use greenformer::coordinator::Tier;
use greenformer::registry::{
    CheckpointEntry, ModelManifest, ModelRegistry, RegistryError, RegistryManifest,
};
use greenformer::tensor::gtz;
use greenformer::util::sha256_hex;

const SEQ: usize = 16;
const PROMPTS: [&[i32]; 4] = [&[1, 2, 3], &[4, 5], &[6], &[7, 8, 9]];
const MAX_NEW: usize = 6;

fn cfg() -> TextModelCfg {
    TextModelCfg { vocab: 64, seq: SEQ, d: 32, heads: 4, layers: 1, ff: 64, classes: 3 }
}

/// Fresh scratch directory for one test's checkpoint + manifest files.
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("gf_hot_swap_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Write a GTZ checkpoint for `seed` and return `(file_name, sha256)`.
fn write_ckpt(dir: &PathBuf, file: &str, seed: u64) -> (String, String) {
    let store = init_text_params(&cfg(), seed);
    let path = dir.join(file);
    gtz::write(&path, &store).unwrap();
    let sha = sha256_hex(&std::fs::read(&path).unwrap());
    (file.to_string(), sha)
}

/// One-model lm manifest over a single `dense` checkpoint.
fn lm_manifest(dir: &PathBuf, version: &str, file: String, sha256: String) -> RegistryManifest {
    RegistryManifest {
        models: vec![ModelManifest {
            name: "m".to_string(),
            family: "lm".to_string(),
            version: version.to_string(),
            default: "dense".to_string(),
            checkpoints: vec![CheckpointEntry { name: "dense".to_string(), file, sha256 }],
            route: None,
        }],
        dir: dir.clone(),
    }
}

fn write_manifest(dir: &PathBuf, name: &str, m: &RegistryManifest) -> PathBuf {
    let path = dir.join(name);
    std::fs::write(&path, m.render()).unwrap();
    path
}

fn greedy_tokens(handle: &greenformer::coordinator::ServerHandle, prompt: &[i32]) -> Vec<i32> {
    handle
        .generate_collect(prompt.to_vec(), MAX_NEW, SamplingCfg::greedy(), Tier::Quality)
        .unwrap()
        .tokens
}

#[test]
fn hot_swap_under_load_pins_old_version_bit_identical() {
    let dir = scratch("swap");
    let (f1, sha1) = write_ckpt(&dir, "m_v1.gtz", 11);
    let (f2, sha2) = write_ckpt(&dir, "m_v2.gtz", 22);
    let v1_path = write_manifest(&dir, "registry_v1.json", &lm_manifest(&dir, "v1", f1, sha1));
    let v2_path = write_manifest(&dir, "registry_v2.json", &lm_manifest(&dir, "v2", f2, sha2));

    // Reference: the v1 tokens for each prompt, from an unswapped registry.
    let reference_reg = ModelRegistry::new();
    assert!(reference_reg.load_and_apply(&v1_path).unwrap().rejected.is_empty());
    let ref_handle = reference_reg.get("m").unwrap().handle();
    let reference: Vec<Vec<i32>> = PROMPTS.iter().map(|p| greedy_tokens(&ref_handle, p)).collect();

    // Live registry: install v1, pin it, then swap to v2 while concurrent
    // sessions run on the pinned version.
    let reg = Arc::new(ModelRegistry::new());
    let report = reg.load_and_apply(&v1_path).unwrap();
    assert_eq!(report.installed, vec!["m".to_string()]);
    let pinned = reg.get("m").unwrap();
    assert_eq!((pinned.version.as_str(), pinned.epoch), ("v1", 1));

    let barrier = Arc::new(Barrier::new(PROMPTS.len() + 1));
    let workers: Vec<_> = PROMPTS
        .iter()
        .map(|prompt| {
            let handle = pinned.handle();
            let barrier = barrier.clone();
            let prompt = prompt.to_vec();
            std::thread::spawn(move || {
                barrier.wait();
                greedy_tokens(&handle, &prompt)
            })
        })
        .collect();
    barrier.wait();
    // Swap races the in-flight generations (the install itself takes long
    // enough to overlap: it re-reads, verifies, and builds the graphs).
    let report = reg.load_and_apply(&v2_path).unwrap();
    assert_eq!(report.installed, vec!["m".to_string()]);
    let got: Vec<Vec<i32>> = workers.into_iter().map(|w| w.join().unwrap()).collect();

    // Sessions that started on v1 finished on v1's parameters, exactly.
    assert_eq!(got, reference);

    // The slot now serves v2 at a higher epoch...
    let current = reg.get("m").unwrap();
    assert_eq!((current.version.as_str(), current.epoch), ("v2", 2));
    let v2_tokens = greedy_tokens(&current.handle(), PROMPTS[0]);
    assert_eq!(v2_tokens.len(), MAX_NEW);

    // ...while the pinned v1 Arc keeps serving the old parameters,
    // still bit-identical to the reference.
    assert_eq!(greedy_tokens(&pinned.handle(), PROMPTS[0]), reference[0]);

    assert_eq!(reg.metrics.installs.load(Ordering::Relaxed), 2);
    assert_eq!(reg.metrics.swaps.load(Ordering::Relaxed), 1);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn verification_failures_reject_without_disturbing_serving_version() {
    let dir = scratch("tamper");
    let (f1, sha1) = write_ckpt(&dir, "m_v1.gtz", 11);
    let v1_path = write_manifest(&dir, "registry_v1.json", &lm_manifest(&dir, "v1", f1, sha1));

    let reg = ModelRegistry::new();
    assert!(reg.load_and_apply(&v1_path).unwrap().rejected.is_empty());
    let before = greedy_tokens(&reg.get("m").unwrap().handle(), PROMPTS[0]);

    // (1) Tampered bytes: flip one byte of the v2 file after pinning its
    // hash. The registry must reject on the hash, not on the decoder.
    let (f2, sha2) = write_ckpt(&dir, "m_v2.gtz", 22);
    let mut bytes = std::fs::read(dir.join(&f2)).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xff;
    std::fs::write(dir.join(&f2), &bytes).unwrap();
    let report = reg.apply_manifest(&lm_manifest(&dir, "v2", f2.clone(), sha2.clone()));
    assert!(report.installed.is_empty());
    match &report.rejected[..] {
        [(name, RegistryError::HashMismatch { expected, actual, .. })] => {
            assert_eq!(name, "m");
            assert_eq!(expected, &sha2);
            assert_ne!(actual, &sha2);
        }
        other => panic!("expected HashMismatch, got {other:?}"),
    }

    // (2) Garbage payload with a *correct* hash: passes verification,
    // rejected by the GTZ decoder — typed as Checkpoint, not a panic.
    let garbage = b"definitely not a gtz checkpoint".to_vec();
    std::fs::write(dir.join("garbage.gtz"), &garbage).unwrap();
    let m = lm_manifest(&dir, "v3", "garbage.gtz".to_string(), sha256_hex(&garbage));
    let report = reg.apply_manifest(&m);
    assert!(matches!(report.rejected[..], [(_, RegistryError::Checkpoint { .. })]));

    // (3) Missing file: typed Io rejection.
    let m = lm_manifest(&dir, "v4", "missing.gtz".to_string(), sha2);
    let report = reg.apply_manifest(&m);
    assert!(matches!(report.rejected[..], [(_, RegistryError::Io { .. })]));

    // Through all three rejections, v1 never stopped serving — same
    // version, same epoch, same tokens.
    let current = reg.get("m").unwrap();
    assert_eq!((current.version.as_str(), current.epoch), ("v1", 1));
    assert_eq!(greedy_tokens(&current.handle(), PROMPTS[0]), before);
    assert_eq!(reg.metrics.rejected_models.load(Ordering::Relaxed), 3);
    assert_eq!(reg.metrics.installs.load(Ordering::Relaxed), 1);
    assert_eq!(reg.metrics.swaps.load(Ordering::Relaxed), 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn unparseable_manifest_rejects_as_a_whole() {
    let dir = scratch("parse");
    let (f1, sha1) = write_ckpt(&dir, "m_v1.gtz", 11);
    let v1_path = write_manifest(&dir, "registry_v1.json", &lm_manifest(&dir, "v1", f1, sha1));

    let reg = ModelRegistry::new();
    assert!(reg.load_and_apply(&v1_path).unwrap().rejected.is_empty());

    // An unknown top-level field is a schema violation: the whole manifest
    // rejects and nothing changes.
    let bad = dir.join("bad.json");
    std::fs::write(&bad, r#"{"format": 1, "models": [], "extra": true}"#).unwrap();
    match reg.load_and_apply(&bad) {
        Err(RegistryError::Parse { detail }) => assert!(detail.contains("extra"), "{detail}"),
        other => panic!("expected Parse rejection, got {other:?}"),
    }
    assert_eq!(reg.metrics.rejected_manifests.load(Ordering::Relaxed), 1);
    assert_eq!(reg.get("m").unwrap().version, "v1");
    let _ = std::fs::remove_dir_all(&dir);
}
