//! End-to-end native training, hermetic (no artifacts, no PJRT): the
//! factorize→train→eval loop that PR 3 makes artifact-free.
//!
//! * `by_design_led_model_learns_polarity` — the fig2-smoke satellite: train
//!   a tiny by-design LED text model a few hundred steps, assert the loss
//!   decreases and held-out accuracy beats chance.
//! * `fig2_by_design_native_smoke` / `fig2_post_training_native_smoke` —
//!   drive the actual Figure-2 harnesses through `FigEnv::Native` at a tiny
//!   scale: every (task, variant) point must come back populated.

use greenformer::backend::native::{init_text_params, synth_fwd_graph, ImageModelCfg, TextModelCfg};
use greenformer::backend::NativeBackend;
use greenformer::data::text::PolarityTask;
use greenformer::eval::eval_classifier;
use greenformer::experiments::{by_design, post_training, ExpParams, FigEnv, NativeFigCfg};
use greenformer::factorize::{auto_fact, AutoFactConfig, Rank, Solver};
use greenformer::train::Trainer;

const BACKEND: NativeBackend = NativeBackend;

fn tiny_text() -> TextModelCfg {
    TextModelCfg {
        vocab: 512, // full task vocabulary
        seq: 64,    // task native length
        d: 32,
        heads: 4,
        layers: 1,
        ff: 64,
        classes: 4,
    }
}

fn tiny_env() -> NativeFigCfg {
    NativeFigCfg {
        text: tiny_text(),
        image: ImageModelCfg {
            hw: 28,
            ch: 1,
            classes: 4,
            c1: 8,
            c2: 16,
            fc: 32,
        },
        batch: 8,
        seed: 42,
        solver: Solver::Svd,
        ..Default::default()
    }
}

fn smoke_params() -> ExpParams {
    ExpParams {
        steps: 15,
        eval_examples: 32,
        ratios: vec![0.5],
        latency_iters: 2,
        k_shots: 4,
        seed: 42,
    }
}

#[test]
fn by_design_led_model_learns_polarity() {
    let cfg = tiny_text();
    let mut params = init_text_params(&cfg, 42);
    let report = auto_fact(
        &mut params,
        &AutoFactConfig {
            rank: Rank::Ratio(0.5),
            solver: Solver::Svd,
            num_iter: 10,
            submodules: None,
            ..Default::default()
        },
    )
    .unwrap();
    assert!(report.n_factorized() > 0, "by-design init must factorize something");

    let ds = PolarityTask::new(cfg.seq, 0);
    let mut trainer = Trainer::native(&BACKEND, "text", "led_r50", 8, params).unwrap();
    trainer.train_classifier(&ds, 300, None, |_| {}).unwrap();
    assert_eq!(trainer.step, 300);

    let early: f32 =
        trainer.history[..10].iter().map(|l| l.loss).sum::<f32>() / 10.0;
    let late = trainer.recent_loss(20);
    assert!(
        late < early - 0.05,
        "loss did not decrease: early {early:.4} late {late:.4}"
    );

    let graph = synth_fwd_graph("text", "led_r50", 8, &trainer.params).unwrap();
    let ev = eval_classifier(&BACKEND, &graph, &trainer.params, &ds, 128, None).unwrap();
    // Chance is 0.5 on the binary task; 128 examples put 3σ at ~0.13.
    assert!(
        ev.accuracy() > 0.6,
        "by-design LED model should beat chance: acc {:.3} ({}/{})",
        ev.accuracy(),
        ev.correct,
        ev.total
    );
}

#[test]
fn fig2_by_design_native_smoke() {
    let env = FigEnv::Native(tiny_env());
    let result = by_design(&env, &smoke_params()).unwrap();
    // 5 tasks × (dense + led_r50).
    assert_eq!(result.points.len(), 10, "{:#?}", result.points);
    for p in &result.points {
        assert!((0.0..=1.0).contains(&p.accuracy), "{p:?}");
        assert!(p.latency > 0.0, "{p:?}");
        assert!(p.rel_performance.is_finite(), "{p:?}");
        assert!(p.n_params > 0, "{p:?}");
    }
    // LED variants are genuinely smaller on the text tasks.
    let dense = result
        .points
        .iter()
        .find(|p| p.task == "polarity" && p.variant == "dense")
        .unwrap();
    let led = result
        .points
        .iter()
        .find(|p| p.task == "polarity" && p.variant == "led_r50")
        .unwrap();
    assert!(led.n_params < dense.n_params);
    assert_eq!(led.ratio, Some(0.5));
    // The render is the CLI artifact; it must include the averages block.
    let text = result.render();
    assert!(text.contains("averaged across tasks"), "{text}");
}

#[test]
fn fig2_post_training_native_smoke() {
    let env = FigEnv::Native(tiny_env());
    let result = post_training(&env, &smoke_params(), Solver::Svd).unwrap();
    assert_eq!(result.points.len(), 10, "{:#?}", result.points);
    for p in &result.points {
        assert!((0.0..=1.0).contains(&p.accuracy), "{p:?}");
        assert!(p.latency > 0.0, "{p:?}");
    }
    // Post-training factorization happened on the *trained* checkpoint:
    // factorized points carry fewer params than their dense baseline.
    for task in ["polarity", "topic", "matching"] {
        let dense = result
            .points
            .iter()
            .find(|p| p.task == task && p.variant == "dense")
            .unwrap();
        let led = result
            .points
            .iter()
            .find(|p| p.task == task && p.variant == "led_r50")
            .unwrap();
        assert!(led.n_params < dense.n_params, "{task}");
    }
}
