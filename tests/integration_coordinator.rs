//! End-to-end coordinator test: real engine, real graphs, concurrent
//! clients through the thread-based serving loop.
//!
//! Hermetic-by-default: when the AOT artifacts are absent or the PJRT
//! runtime is unavailable (offline `xla` stub), each test skips with a
//! visible reason instead of failing.

use std::collections::HashMap;
use std::time::Duration;

use greenformer::coordinator::{
    serve_classifier, BatcherConfig, RoutePolicy, Router, ServeConfig, Tier,
};
use greenformer::data::text::PolarityTask;
use greenformer::data::{Dataset, Split};
use greenformer::tensor::ParamStore;

mod common;

/// Load a variant's init checkpoint, or `None` (with a printed skip reason)
/// when artifacts or the PJRT runtime are unavailable.
fn init_params(model: &str, variant: &str) -> Option<ParamStore> {
    let eng = common::engine("integration_coordinator")?;
    Some(ParamStore::load_gtz(eng.manifest().checkpoint(model, variant).unwrap()).unwrap())
}

macro_rules! init_params_or_skip {
    ($model:expr, $variant:expr) => {
        match init_params($model, $variant) {
            Some(p) => p,
            None => return,
        }
    };
}

#[test]
fn serves_concurrent_requests_exactly_once() {
    let mut stores = HashMap::new();
    stores.insert("dense".to_string(), init_params_or_skip!("text", "dense"));
    stores.insert("led_r25".to_string(), init_params_or_skip!("text", "led_r25"));
    let router = Router::new(
        RoutePolicy::Tiered {
            quality: "dense".into(),
            balanced: "dense".into(),
            fast: "led_r25".into(),
        },
        stores.keys().cloned().collect(),
    )
    .unwrap();
    let handle = serve_classifier(
        greenformer::artifacts_dir(),
        "text",
        stores,
        router,
        ServeConfig::with_batcher(
            BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(3),
            },
            256,
        ),
    )
    .unwrap();

    let ds = PolarityTask::new(64, 1);
    let n = 48;
    let mut joins = Vec::new();
    for i in 0..n {
        let h = handle.clone();
        let ex = ds.example(Split::Eval, i);
        joins.push(std::thread::spawn(move || {
            let tier = if i % 2 == 0 { Tier::Fast } else { Tier::Quality };
            let resp = h.classify(ex.tokens, tier).unwrap();
            (resp.variant, resp.label)
        }));
    }
    let mut fast = 0;
    let mut quality = 0;
    for (i, j) in joins.into_iter().enumerate() {
        let (variant, label) = j.join().unwrap();
        assert!(label < 4);
        if i % 2 == 0 {
            assert_eq!(variant, "led_r25");
            fast += 1;
        } else {
            assert_eq!(variant, "dense");
            quality += 1;
        }
    }
    assert_eq!(fast + quality, n);

    let m = &handle.metrics;
    assert_eq!(
        m.responses.load(std::sync::atomic::Ordering::Relaxed),
        n as u64
    );
    assert_eq!(
        m.requests.load(std::sync::atomic::Ordering::Relaxed),
        n as u64
    );
    assert_eq!(m.errors.load(std::sync::atomic::Ordering::Relaxed), 0);
    let counts = m.variant_counts();
    assert_eq!(counts["led_r25"], fast as u64);
    assert_eq!(counts["dense"], quality as u64);
    // Latency histogram saw every response.
    assert!(m.latency_percentile_us(99.0) > 0);
}

#[test]
fn rejects_unknown_variant_at_startup() {
    let mut stores = HashMap::new();
    stores.insert("dense".to_string(), init_params_or_skip!("text", "dense"));
    // Router validated against its own list, but the server needs graphs for
    // every *store* key; a bogus store key must fail startup synchronously.
    stores.insert("led_r99".to_string(), init_params_or_skip!("text", "dense"));
    let router = Router::new(
        RoutePolicy::Static("dense".into()),
        stores.keys().cloned().collect(),
    )
    .unwrap();
    let res = serve_classifier(
        greenformer::artifacts_dir(),
        "text",
        stores,
        router,
        ServeConfig::with_batcher(BatcherConfig::default(), 16),
    );
    assert!(res.is_err());
}

#[test]
fn deadline_flushes_partial_batches() {
    // A single request into a max_batch=8 server must still be answered
    // (deadline path), well within a generous timeout.
    let mut stores = HashMap::new();
    stores.insert("dense".to_string(), init_params_or_skip!("text", "dense"));
    let router = Router::new(
        RoutePolicy::Static("dense".into()),
        stores.keys().cloned().collect(),
    )
    .unwrap();
    let handle = serve_classifier(
        greenformer::artifacts_dir(),
        "text",
        stores,
        router,
        ServeConfig::with_batcher(
            BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(2),
            },
            16,
        ),
    )
    .unwrap();
    let ds = PolarityTask::new(64, 2);
    let ex = ds.example(Split::Eval, 0);
    let resp = handle.classify(ex.tokens, Tier::Quality).unwrap();
    assert_eq!(resp.variant, "dense");
    // The executed batch padded 7 rows.
    assert_eq!(
        handle
            .metrics
            .padded_rows
            .load(std::sync::atomic::Ordering::Relaxed),
        7
    );
}
