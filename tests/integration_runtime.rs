//! Integration tests over the real AOT artifacts + PJRT engine.
//!
//! These require `make artifacts` to have run; they are the proof that the
//! three layers compose: JAX-exported HLO (with Pallas kernels inlined) ×
//! Rust marshalling × the Greenformer toolkit's factorized checkpoints.
//!
//! Hermetic-by-default: when the artifacts are absent (fresh checkout, CI)
//! or the PJRT runtime is unavailable (offline `xla` stub), every test
//! skips with a visible reason instead of failing.

use greenformer::data::text::PolarityTask;
use greenformer::data::{batch, Split};
use greenformer::factorize::{auto_fact, AutoFactConfig, Rank, Solver};
use greenformer::tensor::ParamStore;
use greenformer::train::Trainer;

mod common;

macro_rules! engine_or_skip {
    () => {
        match common::engine("integration_runtime") {
            Some(eng) => eng,
            None => return,
        }
    };
}

#[test]
fn manifest_lists_all_models_and_variants() {
    let eng = engine_or_skip!();
    let m = eng.manifest();
    for model in ["text", "image", "lm"] {
        let vs = m.variants(model);
        assert!(vs.contains(&"dense".to_string()), "{model}: {vs:?}");
        assert!(vs.iter().any(|v| v.starts_with("led_r")), "{model}: {vs:?}");
    }
}

#[test]
fn fwd_runs_and_output_shape_matches_manifest() {
    let eng = engine_or_skip!();
    let g = eng.manifest().find("text", "dense", "fwd", Some(8)).unwrap().clone();
    let params = ParamStore::load_gtz(eng.manifest().checkpoint("text", "dense").unwrap()).unwrap();
    let ds = PolarityTask::new(g.inputs[0].shape[1], 0);
    let (x, _) = batch(&ds, Split::Eval, 0, g.batch, None);
    let out = eng.run_fwd(&g, &params, &[x]).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].shape, g.outputs[0].shape);
    assert!(out[0].as_f32().unwrap().iter().all(|v| v.is_finite()));
}

#[test]
fn fwd_rejects_wrong_shapes_and_missing_params() {
    let eng = engine_or_skip!();
    let g = eng.manifest().find("text", "dense", "fwd", Some(1)).unwrap().clone();
    let params = ParamStore::load_gtz(eng.manifest().checkpoint("text", "dense").unwrap()).unwrap();
    // Wrong input shape.
    let bad = greenformer::tensor::Tensor::from_i32(&[1, 3], vec![0, 1, 2]);
    assert!(eng.run_fwd(&g, &params, &[bad]).is_err());
    // Missing param.
    let mut short = params.clone();
    short.remove("head/w").unwrap();
    let ds = PolarityTask::new(g.inputs[0].shape[1], 0);
    let (x, _) = batch(&ds, Split::Eval, 0, 1, None);
    assert!(eng.run_fwd(&g, &short, &[x]).is_err());
}

#[test]
fn train_step_reduces_loss_on_fixed_batch() {
    let eng = engine_or_skip!();
    let mut trainer = Trainer::from_init(&eng, "text", "dense").unwrap();
    let ds = PolarityTask::new(64, 0);
    let (x, y) = batch(&ds, Split::Train, 0, trainer.batch_size(), None);
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..8 {
        last = trainer.train_step(&[x.clone(), y.clone()]).unwrap();
        first.get_or_insert(last);
    }
    let first = first.unwrap();
    assert!(last.is_finite() && first.is_finite());
    assert!(last < first, "loss should fall on a fixed batch: {first} -> {last}");
}

#[test]
fn by_design_factorized_variant_trains_too() {
    let eng = engine_or_skip!();
    let mut trainer = Trainer::from_init(&eng, "text", "led_r25").unwrap();
    let ds = PolarityTask::new(64, 0);
    let (x, y) = batch(&ds, Split::Train, 0, trainer.batch_size(), None);
    let l0 = trainer.train_step(&[x.clone(), y.clone()]).unwrap();
    for _ in 0..6 {
        trainer.train_step(&[x.clone(), y.clone()]).unwrap();
    }
    let l1 = trainer.history.last().unwrap().loss;
    assert!(l1 < l0, "{l0} -> {l1}");
}

#[test]
fn rust_factorized_checkpoint_loads_into_led_graph() {
    // The cross-language contract: auto_fact (Rust, SVD) on a dense
    // checkpoint must produce exactly the shapes the led_r50 AOT graph
    // expects, and — when the dense weights genuinely have low rank, as
    // trained weights do (the paper's premise) — the factorized logits
    // must track the dense ones closely.
    let eng = engine_or_skip!();
    let mut dense =
        ParamStore::load_gtz(eng.manifest().checkpoint("text", "dense").unwrap()).unwrap();
    // Rebuild every 2-D weight as an exactly rank-8 product so the SVD
    // truncation at ratio 0.5 (rank >= 32 for these shapes) is lossless.
    use greenformer::linalg::Matrix;
    use greenformer::util::Pcg64;
    let names: Vec<String> = dense.names().to_vec();
    let mut rng = Pcg64::seeded(99);
    for name in names {
        if !name.ends_with("/w") {
            continue;
        }
        let t = dense.get(&name).unwrap();
        if t.ndim() != 2 {
            continue;
        }
        let (m, n) = (t.shape[0], t.shape[1]);
        if greenformer::factorize::rank_for(m, n, 0.5).is_none() {
            continue; // gate will keep it dense anyway
        }
        let scale = (2.0 / (m + n) as f64).sqrt() as f32;
        let u = Matrix::randn(m, 8, scale, &mut rng);
        let v = Matrix::randn(8, n, 0.35, &mut rng);
        let w = u.matmul(&v);
        dense.insert(
            name,
            greenformer::tensor::Tensor::from_f32(&[m, n], w.data),
        );
    }
    let mut fact = dense.clone();
    auto_fact(
        &mut fact,
        &AutoFactConfig {
            rank: Rank::Ratio(0.50),
            solver: Solver::Svd,
            num_iter: 30,
            submodules: None,
            ..Default::default()
        },
    )
    .unwrap();

    let g = eng.manifest().find("text", "led_r50", "fwd", Some(8)).unwrap().clone();
    // Shape check happens inside run_fwd against the manifest specs.
    let ds = PolarityTask::new(64, 0);
    let (x, _) = batch(&ds, Split::Eval, 0, g.batch, None);
    let out_fact = eng.run_fwd(&g, &fact, &[x.clone()]).unwrap();

    let gd = eng.manifest().find("text", "dense", "fwd", Some(8)).unwrap().clone();
    let out_dense = eng.run_fwd(&gd, &dense, &[x]).unwrap();

    let f = out_fact[0].as_f32().unwrap();
    let d = out_dense[0].as_f32().unwrap();
    assert_eq!(f.len(), d.len());
    // Correlation between dense and factorized logits.
    let mean = |v: &[f32]| v.iter().sum::<f32>() / v.len() as f32;
    let (mf, md) = (mean(f), mean(d));
    let mut num = 0.0;
    let mut df = 0.0;
    let mut dd = 0.0;
    for (a, b) in f.iter().zip(d) {
        num += (a - mf) * (b - md);
        df += (a - mf) * (a - mf);
        dd += (b - md) * (b - md);
    }
    let corr = num / (df.sqrt() * dd.sqrt() + 1e-12);
    assert!(
        corr > 0.99,
        "rank-8 weights truncated at rank>=32 must be preserved: corr={corr}"
    );
}

#[test]
fn snmf_factorized_checkpoint_also_runs() {
    let eng = engine_or_skip!();
    let dense = ParamStore::load_gtz(eng.manifest().checkpoint("text", "dense").unwrap()).unwrap();
    let mut fact = dense;
    auto_fact(
        &mut fact,
        &AutoFactConfig {
            rank: Rank::Ratio(0.25),
            solver: Solver::Snmf,
            num_iter: 15,
            submodules: None,
            ..Default::default()
        },
    )
    .unwrap();
    let g = eng.manifest().find("text", "led_r25", "fwd", Some(1)).unwrap().clone();
    let ds = PolarityTask::new(64, 0);
    let (x, _) = batch(&ds, Split::Eval, 0, 1, None);
    let out = eng.run_fwd(&g, &fact, &[x]).unwrap();
    assert!(out[0].as_f32().unwrap().iter().all(|v| v.is_finite()));
}

#[test]
fn executable_cache_hits() {
    let eng = engine_or_skip!();
    let g = eng.manifest().find("text", "dense", "fwd", Some(1)).unwrap().clone();
    let before = eng.cached_executables();
    eng.executable(&g.name).unwrap();
    let after_first = eng.cached_executables();
    eng.executable(&g.name).unwrap();
    assert_eq!(eng.cached_executables(), after_first);
    assert!(after_first > before || before > 0);
}

#[test]
fn image_model_runs_both_variants() {
    let eng = engine_or_skip!();
    let ds = greenformer::data::image::ShapesTask::new(0);
    for variant in ["dense", "led_r50"] {
        let g = eng.manifest().find("image", variant, "fwd", Some(8)).unwrap().clone();
        let params =
            ParamStore::load_gtz(eng.manifest().checkpoint("image", variant).unwrap()).unwrap();
        let (x, _) = batch(&ds, Split::Eval, 0, g.batch, Some((28, 28, 1)));
        let out = eng.run_fwd(&g, &params, &[x]).unwrap();
        assert_eq!(out[0].shape, g.outputs[0].shape, "{variant}");
    }
}

#[test]
fn lm_fwd_produces_vocab_logits() {
    let eng = engine_or_skip!();
    let g = eng.manifest().find("lm", "dense", "fwd", Some(1)).unwrap().clone();
    let params = ParamStore::load_gtz(eng.manifest().checkpoint("lm", "dense").unwrap()).unwrap();
    let corpus = greenformer::data::lm::LmCorpus::new(g.inputs[0].shape[1], 0);
    let x = corpus.batch(0, g.batch);
    let out = eng.run_fwd(&g, &params, &[x]).unwrap();
    assert_eq!(out[0].shape, vec![g.batch, g.inputs[0].shape[1], 512]);
}
