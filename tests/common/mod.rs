//! Shared helper for artifact-dependent integration tests: hand out the
//! engine over the default artifacts dir, or print why the caller skips.
//!
//! (Lives in a subdirectory so cargo does not treat it as a test target.)

use greenformer::runtime::Engine;

/// The engine over the default artifacts dir, or `None` (with a printed
/// skip reason) when artifacts or the PJRT runtime are unavailable. Skip
/// reasons go to stderr; run with `cargo test -- --nocapture` (CI does) to
/// see them from passing tests.
pub fn engine(suite: &str) -> Option<Engine> {
    let dir = greenformer::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!(
            "SKIP {suite}: no AOT artifacts at {dir:?} \
             (build them with `make artifacts` / python/compile/aot.py)"
        );
        return None;
    }
    match Engine::load(dir) {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("SKIP {suite}: engine unavailable: {err:#}");
            None
        }
    }
}
