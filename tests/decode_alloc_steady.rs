//! Steady-state decode must not touch the allocator (PR 5 acceptance).
//!
//! Two layers of verification:
//!
//! 1. **Workspace misses** — `DecodeSession::scratch_alloc_misses()` must
//!    not move across post-warmup steps: every interpreter buffer is served
//!    from the session's arena.
//! 2. **A counting global allocator** — the *total* allocation count of a
//!    steady-state `run_decode_step` call must be constant and tiny (the
//!    returned logits `Tensor` is the single unavoidable per-token
//!    allocation; a small fixed bound covers its shape/data vectors).
//!
//! This file deliberately contains exactly one `#[test]` so no sibling test
//! thread pollutes the allocation counters (integration tests are separate
//! binaries, so other suites cannot interfere). The model is sized so every
//! per-token GEMV stays under the kernel's parallel thresholds — pool
//! workers would otherwise allocate pack scratch on their own threads.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use greenformer::backend::native::{init_text_params, synth_fwd_graph, TextModelCfg};
use greenformer::backend::{Backend, DecodeSession, NativeBackend};
use greenformer::experiments::kron_structured_lm;
use greenformer::factorize::{auto_fact, AutoFactConfig, Solver, TtConfig, WeightPrecision};

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_decode_steps_do_not_allocate_in_the_interpreter() {
    // Small dims keep every GEMM/GEMV on the calling thread (serial kernel
    // paths) so the counter sees only this test's allocations.
    let cfg = TextModelCfg {
        vocab: 64,
        seq: 24,
        d: 24,
        heads: 6,
        layers: 2,
        ff: 48,
        classes: 64,
    };
    let params = init_text_params(&cfg, 11);
    let graph = synth_fwd_graph("lm", "dense", 1, &params).unwrap();
    let be = NativeBackend::new();
    let mut session = DecodeSession::new(&graph, &params).unwrap();

    // Prefill + two warmup steps: the arena learns the step's buffer sizes.
    be.run_decode_step(&graph, &params, &mut session, &[1, 2, 3, 4]).unwrap();
    for t in 0..2 {
        be.run_decode_step(&graph, &params, &mut session, &[t]).unwrap();
    }

    // Steady state: workspace misses frozen, per-step allocation count
    // constant and bounded by the logits-tensor output.
    session.reset_scratch_stats();
    let mut per_step = Vec::new();
    for t in 0..8 {
        let before = ALLOCS.load(Ordering::Relaxed);
        let logits = be.run_decode_step(&graph, &params, &mut session, &[10 + t]).unwrap();
        let after = ALLOCS.load(Ordering::Relaxed);
        per_step.push(after - before);
        assert!(logits.as_f32().unwrap().iter().all(|v| v.is_finite()));
    }
    assert_eq!(
        session.scratch_alloc_misses(),
        0,
        "workspace had to allocate in steady state"
    );
    let first = per_step[0];
    assert!(
        per_step.iter().all(|&c| c == first),
        "per-step allocation counts drifted: {per_step:?}"
    );
    // The returned (vocab,) Tensor is the only per-token allocation the
    // interpreter performs; a few allocs cover its data + shape vectors.
    assert!(first <= 4, "steady-state decode step made {first} allocations");

    // Same contract at int8 (DESIGN.md §12): the session pre-packs the
    // quantized weights once at construction, activation quantization runs
    // in thread-local scratch sized during warmup, and the steady-state
    // step touches the allocator only for the logits tensor.
    let mut session =
        DecodeSession::new_with_precision(&graph, &params, WeightPrecision::Int8).unwrap();
    assert_eq!(session.precision(), WeightPrecision::Int8);
    assert!(session.quant_bytes() > 0, "int8 session must hold a packed store");

    be.run_decode_step(&graph, &params, &mut session, &[1, 2, 3, 4]).unwrap();
    for t in 0..2 {
        be.run_decode_step(&graph, &params, &mut session, &[t]).unwrap();
    }
    session.reset_scratch_stats();
    let mut per_step = Vec::new();
    for t in 0..8 {
        let before = ALLOCS.load(Ordering::Relaxed);
        let logits = be.run_decode_step(&graph, &params, &mut session, &[10 + t]).unwrap();
        let after = ALLOCS.load(Ordering::Relaxed);
        per_step.push(after - before);
        assert!(logits.as_f32().unwrap().iter().all(|v| v.is_finite()));
    }
    assert_eq!(
        session.scratch_alloc_misses(),
        0,
        "int8 workspace had to allocate in steady state"
    );
    let first = per_step[0];
    assert!(
        per_step.iter().all(|&c| c == first),
        "int8 per-step allocation counts drifted: {per_step:?}"
    );
    assert!(first <= 4, "steady-state int8 decode step made {first} allocations");

    // Same contract for a TT-factorized model: the core-chain contraction
    // in `tt_apply_ws` draws every slab-transpose/GEMM buffer from the
    // session workspace, so once warmup has sized the arena the step is
    // allocation-free. Kronecker-structured weights make every linear layer
    // TT-rank-1, so the `tt` solver actually replaces them (unstructured
    // weights would be gate-rejected: full-rank TT holds more floats than
    // dense).
    let mut params = kron_structured_lm(&cfg, 11).unwrap();
    let report = auto_fact(
        &mut params,
        &AutoFactConfig {
            solver: Solver::Tt,
            tt: TtConfig { modes: 2, energy: 0.99, max_rank: None },
            ..Default::default()
        },
    )
    .unwrap();
    assert!(report.n_factorized() > 0, "no layer took the TT path");
    let graph = synth_fwd_graph("lm", "tt", 1, &params).unwrap();
    let mut session = DecodeSession::new(&graph, &params).unwrap();

    be.run_decode_step(&graph, &params, &mut session, &[1, 2, 3, 4]).unwrap();
    for t in 0..2 {
        be.run_decode_step(&graph, &params, &mut session, &[t]).unwrap();
    }
    session.reset_scratch_stats();
    let mut per_step = Vec::new();
    for t in 0..8 {
        let before = ALLOCS.load(Ordering::Relaxed);
        let logits = be.run_decode_step(&graph, &params, &mut session, &[10 + t]).unwrap();
        let after = ALLOCS.load(Ordering::Relaxed);
        per_step.push(after - before);
        assert!(logits.as_f32().unwrap().iter().all(|v| v.is_finite()));
    }
    assert_eq!(
        session.scratch_alloc_misses(),
        0,
        "TT workspace had to allocate in steady state"
    );
    let first = per_step[0];
    assert!(
        per_step.iter().all(|&c| c == first),
        "TT per-step allocation counts drifted: {per_step:?}"
    );
    assert!(first <= 4, "steady-state TT decode step made {first} allocations");
}
