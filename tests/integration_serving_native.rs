//! End-to-end coordinator test over the native backend.
//!
//! Hermetic by construction: runs — never skips — on a fresh checkout with
//! no `artifacts/` directory and no PJRT runtime. Variants are built in
//! Rust (random-init dense + its Random-solver `auto_fact` factorization;
//! see `demo_variants`) and served through the full queue → router →
//! batcher → backend path with concurrent client threads.

use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::time::Duration;

use greenformer::backend::native::{demo_variants, TextModelCfg};
use greenformer::backend::SamplingCfg;
use greenformer::coordinator::{
    serve_classifier, serve_classifier_native, BatcherConfig, RoutePolicy, Router, ServeConfig,
    ShedReason, SpecConfig, Tier, TokenEvent,
};
use greenformer::data::text::PolarityTask;
use greenformer::data::{Dataset, Split};
use greenformer::tensor::ParamStore;

const SEQ: usize = 64;

fn model_cfg() -> TextModelCfg {
    // Full vocab (PolarityTask emits ids up to 511) but a slim trunk so the
    // SVD factorization + serving stays fast in CI.
    TextModelCfg {
        vocab: 512,
        seq: SEQ,
        d: 64,
        heads: 4,
        layers: 2,
        ff: 128,
        classes: 4,
    }
}

/// dense + led_r25 variant checkpoints, built without any artifacts (see
/// `demo_variants` for the Random-solver rationale).
fn variant_stores() -> HashMap<String, ParamStore> {
    let (dense, led) = demo_variants(&model_cfg(), 42, 0.25).unwrap();
    let mut m = HashMap::new();
    m.insert("dense".to_string(), dense);
    m.insert("led_r25".to_string(), led);
    m
}

fn tiered_router(stores: &HashMap<String, ParamStore>) -> Router {
    Router::new(
        RoutePolicy::Tiered {
            quality: "dense".into(),
            balanced: "dense".into(),
            fast: "led_r25".into(),
        },
        stores.keys().cloned().collect(),
    )
    .unwrap()
}

#[test]
fn serves_concurrent_requests_exactly_once_on_native_backend() {
    let stores = variant_stores();
    let router = tiered_router(&stores);
    let handle = serve_classifier_native(
        "text",
        stores,
        router,
        ServeConfig::with_batcher(
            BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(3),
            },
            256,
        ),
    )
    .unwrap();

    let ds = PolarityTask::new(SEQ, 1);
    let n = 48;
    let mut joins = Vec::new();
    for i in 0..n {
        let h = handle.clone();
        let ex = ds.example(Split::Eval, i);
        joins.push(std::thread::spawn(move || {
            let tier = if i % 2 == 0 { Tier::Fast } else { Tier::Quality };
            let resp = h.classify(ex.tokens, tier).unwrap();
            (resp.variant, resp.label, resp.logits.len())
        }));
    }
    let mut fast = 0u64;
    let mut quality = 0u64;
    for (i, j) in joins.into_iter().enumerate() {
        // Exactly one response per request; variant labels match routing.
        let (variant, label, width) = j.join().unwrap();
        assert!(label < 4);
        assert_eq!(width, 4);
        if i % 2 == 0 {
            assert_eq!(variant, "led_r25");
            fast += 1;
        } else {
            assert_eq!(variant, "dense");
            quality += 1;
        }
    }
    assert_eq!(fast + quality, n as u64);

    // Metrics totals reconcile: every request answered, none errored, pad
    // rows never produced a response.
    let m = &handle.metrics;
    assert_eq!(m.requests.load(Ordering::Relaxed), n as u64);
    assert_eq!(m.responses.load(Ordering::Relaxed), n as u64);
    assert_eq!(m.errors.load(Ordering::Relaxed), 0);
    let batches = m.batches.load(Ordering::Relaxed);
    let padded = m.padded_rows.load(Ordering::Relaxed);
    assert!(batches > 0);
    // real rows + pad rows fill the executed batches exactly.
    assert_eq!(batches * 8, n as u64 + padded);
    let counts = m.variant_counts();
    assert_eq!(counts["led_r25"], fast);
    assert_eq!(counts["dense"], quality);
    assert!(m.latency_percentile_us(99.0) > 0);
}

#[test]
fn bad_token_length_gets_error_response_not_a_dispatcher_panic() {
    let stores = variant_stores();
    let router = Router::new(
        RoutePolicy::Static("dense".into()),
        stores.keys().cloned().collect(),
    )
    .unwrap();
    let handle = serve_classifier_native(
        "text",
        stores,
        router,
        ServeConfig::with_batcher(
            BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(2),
            },
            16,
        ),
    )
    .unwrap();

    // Wrong sequence length: must be rejected with an error, not a panic.
    let err = handle.classify(vec![1, 2, 3], Tier::Quality);
    assert!(err.is_err(), "short request must error");
    let msg = format!("{:#}", err.unwrap_err());
    assert!(msg.contains("token length"), "unexpected error: {msg}");

    // Out-of-range token id (vocab is 512): rejected individually, without
    // failing the rest of its batch.
    let err = handle.classify(vec![600; SEQ], Tier::Quality);
    assert!(err.is_err(), "out-of-vocab request must error");
    let msg = format!("{:#}", err.unwrap_err());
    assert!(msg.contains("out of range"), "unexpected error: {msg}");

    // The server survives and keeps answering well-formed requests.
    let ds = PolarityTask::new(SEQ, 2);
    let ex = ds.example(Split::Eval, 0);
    let resp = handle.classify(ex.tokens, Tier::Quality).unwrap();
    assert_eq!(resp.variant, "dense");

    let m = &handle.metrics;
    assert_eq!(m.requests.load(Ordering::Relaxed), 3);
    assert_eq!(m.responses.load(Ordering::Relaxed), 1);
    assert_eq!(m.errors.load(Ordering::Relaxed), 2);
}

/// Small causal LM family (head width = vocab, heads at the zoo's "lm"
/// default of 6 — the server synthesizes its graphs internally, so the cfg
/// must match the default).
fn lm_cfg() -> TextModelCfg {
    TextModelCfg {
        vocab: 64,
        seq: 16,
        d: 24,
        heads: 6,
        layers: 1,
        ff: 48,
        classes: 64,
    }
}

fn lm_stores() -> HashMap<String, ParamStore> {
    let (dense, led) = demo_variants(&lm_cfg(), 7, 0.5).unwrap();
    let mut m = HashMap::new();
    m.insert("dense".to_string(), dense);
    m.insert("led_r50".to_string(), led);
    m
}

fn lm_server() -> greenformer::coordinator::ServerHandle {
    lm_server_with(ServeConfig::with_batcher(BatcherConfig::default(), 128))
}

fn lm_server_with(cfg: ServeConfig) -> greenformer::coordinator::ServerHandle {
    let stores = lm_stores();
    let router = Router::new(
        RoutePolicy::Tiered {
            quality: "dense".into(),
            balanced: "dense".into(),
            fast: "led_r50".into(),
        },
        stores.keys().cloned().collect(),
    )
    .unwrap();
    serve_classifier_native("lm", stores, router, cfg).unwrap()
}

#[test]
fn generate_streams_tokens_and_reconciles_per_token_metrics() {
    let handle = lm_server();
    let prompt_len = 4usize;
    let max_new = 8usize;

    // Streaming contract: Token events with sequential indices, then Done
    // carrying the same tokens in order.
    let sampling = SamplingCfg {
        temperature: 0.8,
        top_k: 8,
        seed: 1,
    };
    let rx = handle
        .generate(vec![1, 2, 3, 4], max_new, sampling, Tier::Quality)
        .unwrap();
    let mut streamed = Vec::new();
    let done = loop {
        match rx.recv().expect("stream ended without a terminal event") {
            TokenEvent::Token { index, token } => {
                assert_eq!(index, streamed.len(), "token indices must be sequential");
                streamed.push(token);
            }
            TokenEvent::Done(resp) => break resp,
            TokenEvent::Failed(msg) => panic!("generation failed: {msg}"),
            TokenEvent::Rejected(reason) => panic!("generation shed: {reason}"),
        }
    };
    assert_eq!(streamed, done.tokens);
    assert_eq!(done.tokens.len(), max_new);
    assert_eq!(done.prefill_tokens, prompt_len);
    assert_eq!(done.variant, "dense");

    // Concurrent generations across tiers; fixed seeds reproduce streams.
    let n_clients = 6usize;
    let mut joins = Vec::new();
    for i in 0..n_clients {
        let h = handle.clone();
        joins.push(std::thread::spawn(move || {
            let tier = if i % 2 == 0 { Tier::Fast } else { Tier::Quality };
            let s = SamplingCfg {
                temperature: 0.8,
                top_k: 8,
                seed: i as u64,
            };
            let resp = h.generate_collect(vec![1, 2, 3, 4], max_new, s, tier).unwrap();
            (i, resp)
        }));
    }
    for j in joins {
        let (i, resp) = j.join().unwrap();
        assert_eq!(resp.tokens.len(), max_new);
        let expect = if i % 2 == 0 { "led_r50" } else { "dense" };
        assert_eq!(resp.variant, expect, "client {i}");
        // Replaying the same seed on the same tier reproduces the stream.
        let s = SamplingCfg {
            temperature: 0.8,
            top_k: 8,
            seed: i as u64,
        };
        let tier = if i % 2 == 0 { Tier::Fast } else { Tier::Quality };
        let replay = handle.generate_collect(vec![1, 2, 3, 4], max_new, s, tier).unwrap();
        assert_eq!(replay.tokens, resp.tokens, "client {i}: seed must reproduce the stream");
    }

    // Per-token metrics reconcile: one request per generation, prompt
    // tokens tallied by prefill, streamed tokens tallied one by one.
    let m = &handle.metrics;
    let generations = (1 + n_clients + n_clients) as u64; // streamed + clients + replays
    assert_eq!(m.requests.load(Ordering::Relaxed), generations);
    assert_eq!(m.responses.load(Ordering::Relaxed), generations);
    assert_eq!(m.decode_sessions.load(Ordering::Relaxed), generations);
    assert_eq!(
        m.prefill_tokens.load(Ordering::Relaxed),
        generations * prompt_len as u64
    );
    assert_eq!(
        m.generated_tokens.load(Ordering::Relaxed),
        generations * max_new as u64
    );
    assert_eq!(m.errors.load(Ordering::Relaxed), 0);
    assert_eq!(handle.queue_depth(), 0);
    let counts = m.variant_counts();
    assert_eq!(counts["dense"] + counts["led_r50"], generations);

    // Continuous-batching counters reconcile exactly regardless of how the
    // scheduler happened to group the streams: each generation's first token
    // comes from its prefill, so every generation contributes exactly
    // `max_new - 1` session-tokens to merged sweeps, however batched.
    assert_eq!(
        m.merged_step_tokens.load(Ordering::Relaxed),
        generations * (max_new as u64 - 1)
    );
    let merged_steps = m.merged_steps.load(Ordering::Relaxed);
    assert!(merged_steps >= 1);
    assert!(merged_steps <= generations * (max_new as u64 - 1));
    assert!(m.decode_batch_occupancy() >= 1.0);
    assert_eq!(m.shed_requests.load(Ordering::Relaxed), 0);
}

#[test]
fn sequential_load_pins_occupancy_at_one_and_admission_sheds_above_capacity() {
    // Phase 1 — strictly sequential load: `generate_collect` blocks until
    // Done and the single-threaded dispatcher retires a session before the
    // next ingest, so every merged sweep carries exactly one session and
    // occupancy is exactly 1.0.
    let handle = lm_server();
    let max_new = 6usize;
    let gens = 3u64;
    for i in 0..gens {
        let s = SamplingCfg {
            temperature: 0.8,
            top_k: 8,
            seed: i,
        };
        let resp = handle.generate_collect(vec![1, 2, 3], max_new, s, Tier::Quality).unwrap();
        assert_eq!(resp.tokens.len(), max_new);
    }
    let m = &handle.metrics;
    let sweep_tokens = gens * (max_new as u64 - 1); // first token of each stream is prefill's
    assert_eq!(m.merged_step_tokens.load(Ordering::Relaxed), sweep_tokens);
    assert_eq!(m.merged_steps.load(Ordering::Relaxed), sweep_tokens);
    assert!((m.decode_batch_occupancy() - 1.0).abs() < f64::EPSILON);
    assert_eq!(m.shed_requests.load(Ordering::Relaxed), 0);

    // Phase 2 — admission control: with max_sessions = 1, a second stream
    // submitted while the first is mid-generation is shed with a typed
    // rejection, counted separately from errors, and the first stream is
    // unaffected. Stream A runs the longest schedule the capacity allows
    // (14 sweeps) so B's request is dequeued while A is still live.
    let handle = lm_server_with(ServeConfig {
        max_sessions: 1,
        ..ServeConfig::default()
    });
    let max_new = 15usize; // prompt 1 + 14 appended fills seq = 16 exactly
    let rx_a = handle
        .generate(vec![1], max_new, SamplingCfg::greedy(), Tier::Quality)
        .unwrap();
    let rx_b = handle
        .generate(vec![2], 4, SamplingCfg::greedy(), Tier::Quality)
        .unwrap();

    match rx_b.recv().expect("shed stream must get a terminal event") {
        TokenEvent::Rejected(ShedReason::SessionsFull { active, max }) => {
            assert_eq!(active, 1);
            assert_eq!(max, 1);
        }
        other => panic!("expected a typed shed, got {other:?}"),
    }
    assert!(rx_b.recv().is_err(), "no events may follow a rejection");

    let done = loop {
        match rx_a.recv().expect("stream A ended without a terminal event") {
            TokenEvent::Token { .. } => {}
            TokenEvent::Done(resp) => break resp,
            other => panic!("stream A must survive the shed, got {other:?}"),
        }
    };
    assert_eq!(done.tokens.len(), max_new);

    // Requests reconcile: admitted + shed, with sheds disjoint from errors.
    let m = &handle.metrics;
    assert_eq!(m.requests.load(Ordering::Relaxed), 2);
    assert_eq!(m.responses.load(Ordering::Relaxed), 1);
    assert_eq!(m.shed_requests.load(Ordering::Relaxed), 1);
    assert_eq!(m.errors.load(Ordering::Relaxed), 0);
    assert_eq!(m.merged_step_tokens.load(Ordering::Relaxed), max_new as u64 - 1);
    assert_eq!(handle.queue_depth(), 0);
}

#[test]
fn classify_and_generate_reject_mismatched_model_families_cleanly() {
    // Classify against an LM family: per-request error, no panic.
    let lm = lm_server();
    let err = lm.classify(vec![1; 16], Tier::Quality);
    assert!(err.is_err(), "classify on an LM variant must error");
    let msg = format!("{:#}", err.unwrap_err());
    assert!(msg.contains("classify is unsupported"), "unexpected error: {msg}");
    // The server keeps decoding fine afterwards.
    let resp = lm
        .generate_collect(vec![1, 2], 3, SamplingCfg::greedy(), Tier::Quality)
        .unwrap();
    assert_eq!(resp.tokens.len(), 3);

    // Generate against a classifier family: Failed event, no panic.
    let stores = variant_stores();
    let router = tiered_router(&stores);
    let text = serve_classifier_native(
        "text",
        stores,
        router,
        ServeConfig::with_batcher(BatcherConfig::default(), 32),
    )
    .unwrap();
    let err = text.generate_collect(vec![1, 2, 3], 4, SamplingCfg::greedy(), Tier::Quality);
    assert!(err.is_err(), "generate on a classifier variant must fail");
    let msg = format!("{:#}", err.unwrap_err());
    assert!(msg.contains("cannot decode"), "unexpected error: {msg}");
    // And classify still works.
    let ds = PolarityTask::new(SEQ, 5);
    let ok = text.classify(ds.example(Split::Eval, 0).tokens, Tier::Quality).unwrap();
    assert_eq!(ok.variant, "dense");
    // Degenerate but well-formed requests (empty prompt, zero budget)
    // finish cleanly with an empty stream — mirroring `backend::generate` —
    // while genuinely bad requests still fail: over-capacity prompt,
    // out-of-vocab token.
    let lm2 = lm_server();
    let empty = lm2.generate_collect(vec![], 4, SamplingCfg::greedy(), Tier::Quality).unwrap();
    assert!(empty.tokens.is_empty() && empty.prefill_tokens == 0);
    let zero = lm2.generate_collect(vec![1], 0, SamplingCfg::greedy(), Tier::Quality).unwrap();
    assert!(zero.tokens.is_empty() && zero.prefill_tokens == 0);
    assert_eq!(lm2.metrics.errors.load(Ordering::Relaxed), 0);
    assert!(lm2
        .generate_collect(vec![0; 17], 4, SamplingCfg::greedy(), Tier::Quality)
        .is_err());
    assert!(lm2
        .generate_collect(vec![64], 4, SamplingCfg::greedy(), Tier::Quality)
        .is_err(), "out-of-vocab prompt token must fail the prefill");
}

/// A spec-enabled LM server: the dispatcher SVD-factorizes an LED draft of
/// every variant at startup and runs speculative sessions in the same
/// continuous-batching sweep as plain ones.
fn lm_spec_server() -> greenformer::coordinator::ServerHandle {
    lm_server_with(ServeConfig {
        spec: Some(SpecConfig {
            draft_ratio: 0.5,
            k: 3,
            adaptive_k: false,
        }),
        ..ServeConfig::default()
    })
}

#[test]
fn speculative_serving_reconciles_metrics_and_matches_plain_greedy_streams() {
    // Solo plain-greedy references per tier, computed on a separate plain
    // server over the identical (seeded) variant stores. Greedy speculative
    // streams through the server must equal these token-for-token.
    let plain = lm_server();
    let prompt = vec![1i32, 2, 3, 4];
    let max_new = 8usize;
    let expect_fast = plain
        .generate_collect(prompt.clone(), max_new, SamplingCfg::greedy(), Tier::Fast)
        .unwrap();
    let expect_quality = plain
        .generate_collect(prompt.clone(), max_new, SamplingCfg::greedy(), Tier::Quality)
        .unwrap();
    drop(plain);

    // Pure-spec workload: 6 concurrent speculative clients, no plain ones,
    // so the speculation ledger must account for EVERY generated token.
    let handle = lm_spec_server();
    let n_clients = 6usize;
    let mut joins = Vec::new();
    for i in 0..n_clients {
        let h = handle.clone();
        let p = prompt.clone();
        joins.push(std::thread::spawn(move || {
            let tier = if i % 2 == 0 { Tier::Fast } else { Tier::Quality };
            let resp = h
                .generate_speculative_collect(p, max_new, SamplingCfg::greedy(), tier)
                .unwrap();
            (i, resp)
        }));
    }
    for j in joins {
        let (i, resp) = j.join().unwrap();
        let expect = if i % 2 == 0 { &expect_fast } else { &expect_quality };
        assert_eq!(resp.variant, expect.variant, "client {i}");
        assert_eq!(
            resp.tokens, expect.tokens,
            "client {i}: speculative greedy stream diverged from plain greedy"
        );
        assert_eq!(resp.tokens.len(), max_new);
    }

    // Exact reconciliation under concurrent load: every emitted token is an
    // accepted draft or a target-sampled correction — no slack term.
    let m = &handle.metrics;
    let generated = m.generated_tokens.load(Ordering::Relaxed);
    let drafted = m.drafted_tokens.load(Ordering::Relaxed);
    let accepted = m.accepted_tokens.load(Ordering::Relaxed);
    let corrections = m.spec_corrections.load(Ordering::Relaxed);
    let rollbacks = m.spec_rollbacks.load(Ordering::Relaxed);
    assert_eq!(generated, (n_clients * max_new) as u64);
    assert_eq!(
        generated,
        accepted + corrections,
        "speculation ledger must account for every generated token"
    );
    assert!(drafted > 0, "speculative sessions must actually draft");
    assert!(accepted <= drafted);
    let rate = m.acceptance_rate();
    assert!(
        rate > 0.0 && rate <= 1.0,
        "acceptance rate out of (0, 1]: {rate} (SVD draft at ratio 0.5 must win sometimes)"
    );
    // A rollback is recorded per verify round that rejected >= 1 draft, so
    // rollbacks can never exceed the total number of rejected drafts.
    assert!(
        rollbacks <= drafted - accepted,
        "rollbacks ({rollbacks}) exceed rejected drafts ({})",
        drafted - accepted
    );
    assert_eq!(m.requests.load(Ordering::Relaxed), n_clients as u64);
    assert_eq!(m.responses.load(Ordering::Relaxed), n_clients as u64);
    assert_eq!(m.errors.load(Ordering::Relaxed), 0);
    assert_eq!(m.prefill_tokens.load(Ordering::Relaxed), (n_clients * prompt.len()) as u64);
    assert_eq!(handle.queue_depth(), 0);

    // Degenerate speculative requests finish cleanly too (checked before
    // the engine choice, mirroring the plain path).
    let empty = handle
        .generate_speculative_collect(vec![], max_new, SamplingCfg::greedy(), Tier::Quality)
        .unwrap();
    assert!(empty.tokens.is_empty() && empty.prefill_tokens == 0);
    assert_eq!(m.errors.load(Ordering::Relaxed), 0);
}

#[test]
fn mixed_fleet_spec_and_plain_streams_share_sweeps_and_match_solo_references() {
    // Solo references on a plain server (same seeded stores).
    let plain = lm_server();
    let prompt = vec![2i32, 3, 5];
    let max_new = 6usize;
    let expect_fast = plain
        .generate_collect(prompt.clone(), max_new, SamplingCfg::greedy(), Tier::Fast)
        .unwrap();
    let expect_quality = plain
        .generate_collect(prompt.clone(), max_new, SamplingCfg::greedy(), Tier::Quality)
        .unwrap();
    drop(plain);

    // Mixed fleet on one spec-enabled server: 3 speculative + 3 plain
    // clients decoding concurrently, sharing the same dispatcher sweep.
    // Every stream — whichever engine carried it — must equal its solo
    // plain-greedy reference: batching and speculation change the schedule,
    // never the tokens.
    let handle = lm_spec_server();
    let mut joins = Vec::new();
    for i in 0..6usize {
        let h = handle.clone();
        let p = prompt.clone();
        joins.push(std::thread::spawn(move || {
            let tier = if i % 2 == 0 { Tier::Fast } else { Tier::Quality };
            let resp = if i < 3 {
                h.generate_speculative_collect(p, max_new, SamplingCfg::greedy(), tier)
            } else {
                h.generate_collect(p, max_new, SamplingCfg::greedy(), tier)
            }
            .unwrap();
            (i, resp)
        }));
    }
    for j in joins {
        let (i, resp) = j.join().unwrap();
        let expect = if i % 2 == 0 { &expect_fast } else { &expect_quality };
        let engine = if i < 3 { "spec" } else { "plain" };
        assert_eq!(
            resp.tokens, expect.tokens,
            "client {i} ({engine}): stream diverged from its solo reference"
        );
    }
    let m = &handle.metrics;
    assert_eq!(m.responses.load(Ordering::Relaxed), 6);
    assert_eq!(m.errors.load(Ordering::Relaxed), 0);
    // Only the 3 speculative sessions touched the speculation ledger.
    assert!(m.drafted_tokens.load(Ordering::Relaxed) > 0);
    assert!(
        m.accepted_tokens.load(Ordering::Relaxed) + m.spec_corrections.load(Ordering::Relaxed)
            <= m.generated_tokens.load(Ordering::Relaxed),
        "plain streams generate tokens outside the speculation ledger"
    );
    assert_eq!(handle.queue_depth(), 0);
}

#[test]
fn speculative_request_on_spec_disabled_server_fails_cleanly() {
    // No `ServeConfig::spec`: a speculative request gets a per-request
    // Failed event naming the missing config, and the server keeps serving
    // plain generations afterwards.
    let handle = lm_server();
    let err = handle.generate_speculative_collect(vec![1, 2], 4, SamplingCfg::greedy(), Tier::Quality);
    assert!(err.is_err(), "speculative decode must fail when spec is not configured");
    let msg = format!("{:#}", err.unwrap_err());
    assert!(msg.contains("not enabled"), "unexpected error: {msg}");

    let resp = handle
        .generate_collect(vec![1, 2], 4, SamplingCfg::greedy(), Tier::Quality)
        .unwrap();
    assert_eq!(resp.tokens.len(), 4);
    assert_eq!(handle.metrics.errors.load(Ordering::Relaxed), 1);
}

#[test]
fn serve_classifier_auto_falls_back_to_native_without_artifacts() {
    // Point at a directory with no manifest: selection must fall back to the
    // native backend and still serve.
    let stores = variant_stores();
    let router = tiered_router(&stores);
    let handle = serve_classifier(
        std::env::temp_dir().join("gf-no-artifacts-here"),
        "text",
        stores,
        router,
        ServeConfig::with_batcher(
            BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(2),
            },
            32,
        ),
    )
    .unwrap();
    let ds = PolarityTask::new(SEQ, 3);
    let resp = handle
        .classify(ds.example(Split::Eval, 1).tokens, Tier::Fast)
        .unwrap();
    assert_eq!(resp.variant, "led_r25");
    assert_eq!(handle.metrics.errors.load(Ordering::Relaxed), 0);
}
