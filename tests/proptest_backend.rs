//! Randomized property tests for the native backend (in-tree generator over
//! `Pcg64` — proptest is unavailable offline; the methodology is the same:
//! many random cases per invariant, failing seed printed on panic). Runs
//! hermetically: no artifacts, no PJRT.
//!
//! Invariants:
//! * LED forward `x·a·b + bias` ≡ dense forward `x·w + bias` when `w = a·b`
//!   exactly, within 1e-4 (relative) — the paper's signature-preservation
//!   contract, at the layer level and through the whole model;
//! * `NativeBackend` output is invariant to batch padding: extra PAD rows
//!   never change the logits of real rows.

use greenformer::backend::native::{self, init_text_params, synth_fwd_graph, TextModelCfg};
use greenformer::backend::{Backend, NativeBackend};
use greenformer::linalg::Matrix;
use greenformer::tensor::{ParamStore, Tensor};
use greenformer::util::Pcg64;

const CASES: usize = 60;

fn close(a: f32, b: f32, tol: f32) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

#[test]
fn led_forward_equals_dense_when_factors_exact() {
    for seed in 0..CASES as u64 {
        let mut rng = Pcg64::new(seed, 200);
        let m = 1 + rng.below(16);
        let k = 1 + rng.below(48);
        let n = 1 + rng.below(48);
        let r = 1 + rng.below(k.min(n));
        let a = Matrix::randn(k, r, 0.7, &mut rng);
        let b = Matrix::randn(r, n, 0.7, &mut rng);
        let w = a.matmul(&b);
        let mut bias = vec![0.0f32; n];
        rng.fill_normal(&mut bias, 0.5);
        let x = Matrix::randn(m, k, 1.0, &mut rng);

        let mut dense = ParamStore::new();
        dense.insert("fc/w", Tensor::from_f32(&[k, n], w.data.clone()));
        dense.insert("fc/bias", Tensor::from_f32(&[n], bias.clone()));
        let mut led = ParamStore::new();
        led.insert("fc/a", Tensor::from_f32(&[k, r], a.data.clone()));
        led.insert("fc/b", Tensor::from_f32(&[r, n], b.data.clone()));
        led.insert("fc/bias", Tensor::from_f32(&[n], bias));

        let (nd, yd) = native::apply_linear(&dense, "fc", m, k, &x.data).unwrap();
        let (nl, yl) = native::apply_linear(&led, "fc", m, k, &x.data).unwrap();
        assert_eq!(nd, n, "seed {seed}");
        assert_eq!(nl, n, "seed {seed}");
        for (d, l) in yd.iter().zip(&yl) {
            assert!(close(*d, *l, 1e-4), "seed {seed} (m={m} k={k} n={n} r={r}): {d} vs {l}");
        }
    }
}

#[test]
fn whole_model_led_forward_matches_dense_when_factors_exact() {
    // Replace both FFN weights of a one-block model with exact a·b products
    // and check the end-to-end logits agree (through embeddings, layernorms,
    // attention and GELU).
    for seed in 0..8u64 {
        let mut rng = Pcg64::new(seed, 201);
        let cfg = TextModelCfg {
            vocab: 64,
            seq: 10,
            d: 32,
            heads: 4,
            layers: 1,
            ff: 48,
            classes: 4,
        };
        let mut dense = init_text_params(&cfg, seed);
        let mut led = dense.clone();
        for (prefix, k, n) in [("block0/fc1", cfg.d, cfg.ff), ("block0/fc2", cfg.ff, cfg.d)] {
            let r = 1 + rng.below(k.min(n) / 2);
            let a = Matrix::randn(k, r, 0.15, &mut rng);
            let b = Matrix::randn(r, n, 0.15, &mut rng);
            let w = a.matmul(&b);
            dense.insert(format!("{prefix}/w"), Tensor::from_f32(&[k, n], w.data));
            led.remove(&format!("{prefix}/w"));
            led.insert(format!("{prefix}/a"), Tensor::from_f32(&[k, r], a.data));
            led.insert(format!("{prefix}/b"), Tensor::from_f32(&[r, n], b.data));
        }
        led.sort_canonical();

        let batch = 1 + rng.below(3);
        let toks: Vec<i32> = (0..batch * cfg.seq).map(|_| rng.below(cfg.vocab) as i32).collect();
        let x = Tensor::from_i32(&[batch, cfg.seq], toks);
        let be = NativeBackend::new();
        let gd = synth_fwd_graph("text", "dense", batch, &dense).unwrap();
        let gl = synth_fwd_graph("text", "led", batch, &led).unwrap();
        let yd = be.run_fwd(&gd, &dense, &[x.clone()]).unwrap();
        let yl = be.run_fwd(&gl, &led, &[x]).unwrap();
        for (d, l) in yd[0].as_f32().unwrap().iter().zip(yl[0].as_f32().unwrap()) {
            assert!(close(*d, *l, 1e-3), "seed {seed}: {d} vs {l}");
        }
    }
}

#[test]
fn native_output_invariant_to_batch_padding() {
    for seed in 0..12u64 {
        let mut rng = Pcg64::new(seed, 202);
        let cfg = TextModelCfg {
            vocab: 96,
            seq: 8 + rng.below(9),
            d: 32,
            heads: 4,
            layers: 1 + rng.below(2),
            ff: 48,
            classes: 4,
        };
        let params = init_text_params(&cfg, seed);
        let b = 1 + rng.below(4);
        let pad = 1 + rng.below(5);
        let toks: Vec<i32> = (0..b * cfg.seq).map(|_| rng.below(cfg.vocab) as i32).collect();
        let mut padded = toks.clone();
        padded.resize((b + pad) * cfg.seq, 0); // PAD rows of token 0

        let be = NativeBackend::new();
        let g1 = synth_fwd_graph("text", "dense", b, &params).unwrap();
        let g2 = synth_fwd_graph("text", "dense", b + pad, &params).unwrap();
        let y1 = be
            .run_fwd(&g1, &params, &[Tensor::from_i32(&[b, cfg.seq], toks)])
            .unwrap();
        let y2 = be
            .run_fwd(&g2, &params, &[Tensor::from_i32(&[b + pad, cfg.seq], padded)])
            .unwrap();
        assert_eq!(y1[0].shape, vec![b, cfg.classes]);
        assert_eq!(y2[0].shape, vec![b + pad, cfg.classes]);
        let (l1, l2) = (y1[0].as_f32().unwrap(), y2[0].as_f32().unwrap());
        for i in 0..b * cfg.classes {
            assert!(
                (l1[i] - l2[i]).abs() < 1e-5,
                "seed {seed} idx {i}: {} vs {}",
                l1[i],
                l2[i]
            );
        }
    }
}
