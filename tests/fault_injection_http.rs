//! Fault injection against the HTTP front end: malformed request lines,
//! truncated bodies, oversized heads, slow-loris peers, mid-stream
//! disconnects and connection-ceiling pressure. The contract under test is
//! uniform — no panics, no leaked workers or sessions, a structured status
//! for every byte stream the server answers, and exact metrics
//! reconciliation afterwards.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use greenformer::backend::native::{init_text_params, TextModelCfg};
use greenformer::registry::ModelRegistry;
use greenformer::serve_http::{client, HttpConfig, HttpServer};
use greenformer::tensor::ParamStore;

const CLF_SEQ: usize = 8;
const GEN_SEQ: usize = 16;
const T: Duration = Duration::from_secs(10);

fn one_variant(cfg: &TextModelCfg, seed: u64) -> HashMap<String, ParamStore> {
    let mut m = HashMap::new();
    m.insert("dense".to_string(), init_text_params(cfg, seed));
    m
}

fn registry() -> Arc<ModelRegistry> {
    let clf_cfg =
        TextModelCfg { vocab: 64, seq: CLF_SEQ, d: 32, heads: 4, layers: 1, ff: 64, classes: 3 };
    let gen_cfg =
        TextModelCfg { vocab: 64, seq: GEN_SEQ, d: 32, heads: 4, layers: 1, ff: 64, classes: 3 };
    let reg = Arc::new(ModelRegistry::new());
    reg.install_local("clf", "text", "v1", "dense", one_variant(&clf_cfg, 7), None).unwrap();
    reg.install_local("gen", "lm", "v1", "dense", one_variant(&gen_cfg, 9), None).unwrap();
    reg
}

/// Tight limits so every bound is cheap to hit from a test.
fn small_cfg() -> HttpConfig {
    HttpConfig {
        max_header_bytes: 256,
        max_body_bytes: 512,
        header_deadline: Duration::from_millis(400),
        body_deadline: Duration::from_millis(400),
        write_timeout: Duration::from_secs(2),
        max_connections: 32,
        max_generate_tokens: 16,
    }
}

/// The front-end counters must reconcile exactly: every answered request
/// landed in exactly one status class.
fn assert_reconciled(server: &HttpServer) {
    let m = &server.metrics;
    let total = m.requests.load(Ordering::Relaxed);
    let accounted = m.ok.load(Ordering::Relaxed)
        + m.client_errors.load(Ordering::Relaxed)
        + m.server_errors.load(Ordering::Relaxed)
        + m.shed.load(Ordering::Relaxed);
    assert_eq!(total, accounted, "status classes must partition requests");
}

/// Wait (bounded) until no worker connections remain.
fn wait_drained(server: &HttpServer) {
    let deadline = Instant::now() + Duration::from_secs(5);
    while server.active_connections() > 0 {
        assert!(Instant::now() < deadline, "worker connections leaked");
        std::thread::sleep(Duration::from_millis(10));
    }
}

#[test]
fn malformed_and_oversized_inputs_yield_structured_statuses() {
    let server = HttpServer::bind("127.0.0.1:0", registry(), small_cfg()).unwrap();
    let addr = server.local_addr();

    let big_header = format!(
        "GET /v1/healthz HTTP/1.1\r\nx-pad: {}\r\n\r\n",
        "a".repeat(300)
    );
    let cases: Vec<(Vec<u8>, u16, &str)> = vec![
        (b"GARBAGE\r\n\r\n".to_vec(), 400, "unparseable request line"),
        (b"GET /v1/healthz\r\n\r\n".to_vec(), 400, "two-part request line"),
        (b"GET /v1/healthz HTTP/2.0\r\n\r\n".to_vec(), 400, "unsupported protocol"),
        (b"DELETE /v1/classify HTTP/1.1\r\n\r\n".to_vec(), 405, "wrong method on known route"),
        (b"GET /v1/nope HTTP/1.1\r\n\r\n".to_vec(), 404, "unknown route"),
        (b"POST /v1/classify HTTP/1.1\r\n\r\n".to_vec(), 411, "POST without content-length"),
        (
            b"POST /v1/classify HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n".to_vec(),
            501,
            "chunked request body",
        ),
        (
            b"POST /v1/classify HTTP/1.1\r\nContent-Length: 600\r\n\r\n".to_vec(),
            413,
            "declared body beyond the cap",
        ),
        (
            b"POST /v1/classify HTTP/1.1\r\nContent-Length: nope\r\n\r\n".to_vec(),
            400,
            "non-numeric content-length",
        ),
        (big_header.into_bytes(), 431, "oversized request head"),
        (
            b"POST /v1/classify HTTP/1.1\r\nContent-Length: 50\r\n\r\n{".to_vec(),
            400,
            "body truncated by peer close",
        ),
    ];

    for (raw, want, what) in cases {
        let bytes = client::request_raw(addr, &raw, T).unwrap();
        let reply = client::parse_response(&bytes)
            .unwrap_or_else(|e| panic!("{what}: unparseable reply: {e}"));
        assert_eq!(reply.status, want, "{what}: {}", reply.body_text());
        // Every rejection carries the structured error envelope.
        let err = reply.json().unwrap_or_else(|e| panic!("{what}: non-JSON body: {e}"));
        assert_eq!(err.get("error").unwrap().usize_or("status", 0), want as usize, "{what}");
    }

    // The server is still healthy after all of that.
    let r = client::request(addr, "/v1/healthz", None, T).unwrap();
    assert_eq!(r.status, 200);

    wait_drained(&server);
    assert_reconciled(&server);
    server.shutdown().unwrap();
}

#[test]
fn slow_loris_peer_is_evicted_with_408() {
    let server = HttpServer::bind("127.0.0.1:0", registry(), small_cfg()).unwrap();
    let addr = server.local_addr();

    // Dribble a partial head and then stall, keeping the socket open. The
    // server must evict us at `header_deadline` rather than hold a worker.
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    s.write_all(b"GET /v1/healthz HT").unwrap();
    let mut raw = Vec::new();
    s.read_to_end(&mut raw).unwrap();
    let reply = client::parse_response(&raw).unwrap();
    assert_eq!(reply.status, 408, "{}", reply.body_text());
    assert!(server.metrics.evictions.load(Ordering::Relaxed) >= 1);

    // A silent peer (connect, write nothing, vanish) must not produce a
    // response or leak a worker either.
    drop(TcpStream::connect(addr).unwrap());

    let r = client::request(addr, "/v1/healthz", None, T).unwrap();
    assert_eq!(r.status, 200);
    wait_drained(&server);
    assert_reconciled(&server);
    server.shutdown().unwrap();
}

#[test]
fn mid_stream_disconnect_during_generate_reconciles() {
    let reg = registry();
    let server = HttpServer::bind("127.0.0.1:0", reg.clone(), small_cfg()).unwrap();
    let addr = server.local_addr();
    let handle = reg.get("gen").unwrap().handle();

    // Start a streaming generation, read a few bytes of the response, then
    // vanish. The dispatcher must run the session to completion on its
    // buffered channel; nothing may panic, wedge, or leak.
    let body = r#"{"model":"gen","prompt":[1,2,3],"max_new":12}"#;
    let raw = format!(
        "POST /v1/generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    );
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    s.write_all(raw.as_bytes()).unwrap();
    let mut first = [0u8; 16];
    let n = s.read(&mut first).unwrap();
    assert!(n > 0, "stream head never arrived");
    drop(s);

    // The abandoned session must drain: every submitted request answered,
    // queue depth back to zero, no dispatcher errors.
    let m = handle.metrics.clone();
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let requests = m.requests.load(Ordering::Relaxed);
        let responses = m.responses.load(Ordering::Relaxed);
        if requests == responses && handle.queue_depth() == 0 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "abandoned session never drained: {requests} submitted, {responses} answered"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(m.errors.load(Ordering::Relaxed), 0);

    // The same model still serves complete streams afterwards.
    let r = client::request(addr, "/v1/generate", Some(body), T).unwrap();
    assert_eq!(r.status, 200, "{}", r.body_text());
    let events = r.ndjson().unwrap();
    assert_eq!(events.last().unwrap().str_or("event", ""), "done");

    wait_drained(&server);
    assert_reconciled(&server);
    server.shutdown().unwrap();
}

#[test]
fn connection_ceiling_rejects_inline_then_recovers() {
    let mut cfg = small_cfg();
    cfg.max_connections = 2;
    // Generous read deadline so the idle sockets below keep their workers
    // occupied for the whole test.
    cfg.header_deadline = Duration::from_secs(3);
    let server = HttpServer::bind("127.0.0.1:0", registry(), cfg).unwrap();
    let addr = server.local_addr();

    // Occupy every worker slot with idle connections.
    let hold_a = TcpStream::connect(addr).unwrap();
    let hold_b = TcpStream::connect(addr).unwrap();
    let deadline = Instant::now() + Duration::from_secs(2);
    while server.active_connections() < 2 {
        assert!(Instant::now() < deadline, "idle connections never occupied workers");
        std::thread::sleep(Duration::from_millis(5));
    }

    // The next arrival is answered 503 inline — bounded work, typed shed.
    let r = client::request(addr, "/v1/healthz", None, T).unwrap();
    assert_eq!(r.status, 503, "{}", r.body_text());
    assert_eq!(r.headers.get("retry-after").map(String::as_str), Some("1"));
    assert!(server.metrics.conns_rejected.load(Ordering::Relaxed) >= 1);

    // Release the slots; the server must recover without intervention.
    drop(hold_a);
    drop(hold_b);
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let r = client::request(addr, "/v1/healthz", None, T).unwrap();
        if r.status == 200 {
            break;
        }
        assert!(Instant::now() < deadline, "server never recovered after ceiling release");
        std::thread::sleep(Duration::from_millis(20));
    }

    wait_drained(&server);
    assert_reconciled(&server);
    server.shutdown().unwrap();
}
