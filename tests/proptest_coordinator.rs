//! Randomized property tests for the coordinator's pure logic
//! (in-tree generator over `Pcg64` — proptest is unavailable offline, the
//! methodology is the same: many random cases per invariant, with the
//! failing seed printed on panic).
//!
//! Invariants (see coordinator::server docs):
//! * batches never exceed max_batch; size-triggered flushes are exactly full;
//! * every pushed id appears in exactly one flushed batch, in FIFO order;
//! * padding rows = artifact batch − members, never negative;
//! * the deadline flush fires iff the oldest pending waited ≥ max_wait;
//! * routing always returns an available variant.

use std::time::{Duration, Instant};

use greenformer::coordinator::batcher::{plan, Batcher, BatcherConfig};
use greenformer::coordinator::{RoutePolicy, Router, Tier};
use greenformer::util::Pcg64;

const CASES: usize = 300;

#[test]
fn batcher_never_exceeds_max_and_preserves_fifo() {
    for seed in 0..CASES as u64 {
        let mut rng = Pcg64::new(seed, 100);
        let max_batch = 1 + rng.below(16);
        let mut b = Batcher::new(BatcherConfig {
            max_batch,
            max_wait: Duration::from_secs(3600), // size-only in this test
        });
        let n = rng.below(120);
        let now = Instant::now();
        let mut flushed: Vec<usize> = Vec::new();
        for id in 0..n {
            if let Some(batch) = b.push(id, now) {
                assert_eq!(batch.len(), max_batch, "seed {seed}: size flush must be full");
                flushed.extend(batch);
            }
        }
        if let Some(batch) = b.flush() {
            assert!(batch.len() <= max_batch, "seed {seed}");
            flushed.extend(batch);
        }
        // Exactly-once, FIFO.
        assert_eq!(flushed, (0..n).collect::<Vec<_>>(), "seed {seed}");
        assert_eq!(b.pending(), 0);
    }
}

#[test]
fn plan_padding_arithmetic() {
    for seed in 0..CASES as u64 {
        let mut rng = Pcg64::new(seed, 101);
        let artifact = 1 + rng.below(64);
        let members = rng.below(artifact + 1);
        let ids: Vec<usize> = (0..members).collect();
        let p = plan(ids.clone(), artifact);
        assert_eq!(p.members, ids);
        assert_eq!(p.pad_rows, artifact - members, "seed {seed}");
        assert_eq!(p.members.len() + p.pad_rows, artifact);
    }
}

#[test]
fn deadline_flush_fires_exactly_when_oldest_expires() {
    for seed in 0..CASES as u64 {
        let mut rng = Pcg64::new(seed, 102);
        let wait_ms = 1 + rng.below(50) as u64;
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 1000,
            max_wait: Duration::from_millis(wait_ms),
        });
        let t0 = Instant::now();
        let n = 1 + rng.below(10);
        for id in 0..n {
            // All pushed within the window.
            b.push(id, t0 + Duration::from_millis(rng.below(wait_ms as usize) as u64));
        }
        // Hmm: oldest is the FIRST push at t0+something; poll before t0+wait
        // of the first push must not flush if strictly before.
        assert!(
            b.poll_deadline(t0).is_none(),
            "seed {seed}: cannot flush before any deadline"
        );
        let late = t0 + Duration::from_millis(wait_ms * 3);
        let batch = b.poll_deadline(late).expect("must flush after the window");
        assert_eq!(batch.len(), n, "seed {seed}");
        assert!(b.poll_deadline(late).is_none(), "seed {seed}: no double flush");
    }
}

#[test]
fn time_to_deadline_is_monotone_nonincreasing() {
    let mut b = Batcher::new(BatcherConfig {
        max_batch: 100,
        max_wait: Duration::from_millis(100),
    });
    let t0 = Instant::now();
    b.push(0, t0);
    let d1 = b.time_to_deadline(t0).unwrap();
    let d2 = b.time_to_deadline(t0 + Duration::from_millis(40)).unwrap();
    let d3 = b.time_to_deadline(t0 + Duration::from_millis(200)).unwrap();
    assert!(d1 >= d2);
    assert_eq!(d3, Duration::ZERO);
}

#[test]
fn router_always_returns_available_variant() {
    let variants: Vec<String> = vec!["dense".into(), "led_r50".into(), "led_r10".into()];
    let policies = [
        RoutePolicy::Static("led_r50".into()),
        RoutePolicy::Tiered {
            quality: "dense".into(),
            balanced: "led_r50".into(),
            fast: "led_r10".into(),
        },
        RoutePolicy::Adaptive {
            quality: "dense".into(),
            balanced: "led_r50".into(),
            fast: "led_r10".into(),
            low: 3,
            high: 9,
        },
    ];
    for policy in policies {
        let r = Router::new(policy, variants.clone()).unwrap();
        let mut rng = Pcg64::seeded(7);
        for _ in 0..CASES {
            let tier = match rng.below(3) {
                0 => Tier::Quality,
                1 => Tier::Balanced,
                _ => Tier::Fast,
            };
            let depth = rng.below(40);
            let v = r.route(tier, depth);
            assert!(variants.iter().any(|a| a == v));
        }
    }
}

#[test]
fn adaptive_router_is_monotone_in_depth() {
    // Deeper queue must never route to a *slower* (higher-quality) variant.
    let ladder = ["dense", "led_r50", "led_r10"]; // quality -> fast
    let rung = |v: &str| ladder.iter().position(|&l| l == v).unwrap();
    let r = Router::new(
        RoutePolicy::Adaptive {
            quality: "dense".into(),
            balanced: "led_r50".into(),
            fast: "led_r10".into(),
            low: 4,
            high: 12,
        },
        ladder.iter().map(|s| s.to_string()).collect(),
    )
    .unwrap();
    let mut prev = 0;
    for depth in 0..40 {
        let cur = rung(r.route(Tier::Quality, depth));
        assert!(cur >= prev, "depth {depth}: rung went backwards");
        prev = cur;
    }
}
