#!/usr/bin/env python3
"""Derive the pinned TT-SVD constants for tests/golden_data.rs.

Independent numpy reimplementation of the TT-SVD sweep in
``rust/src/factorize/tt.rs`` (grouped-pair permutation, per-unfolding
energy-budgeted truncation, diag(s)@Vt carry), over the same seed-0 weight
the Rust test regenerates from its own PCG64. The pinned quantities are all
gauge-invariant — internal TT ranks, relative reconstruction error, and
row-0 probes of the reconstructed weight — so a LAPACK-vs-Jacobi SVD
difference cannot shift them beyond float noise as long as the truncation
gaps are healthy (this script asserts they are before printing anything).

The weight is a 4-term Kronecker sum with geometrically decaying scales
(0.5**l), so the single two-mode unfolding has singular-value gaps of ~2x
at every candidate rank: the τ = 0.95 budget lands on rank 3 with wide
margins, and the truncated subspace is well-conditioned — exactly what a
cross-implementation pin needs.

Usage:
    python3 python/tools/derive_tt_golden.py
"""

from __future__ import annotations

import math

import numpy as np

F = np.float32
MASK128 = (1 << 128) - 1
MULT = 0x2360ED051FC65DA44385DF649FCCF645

M = N = 64
MODES = 2
ENERGY = 0.95
TERMS = 4


# ---------------------------------------------------------------------------
# PCG64 (XSL-RR 128/64) — mirror of rust/src/util/rng.rs
# ---------------------------------------------------------------------------

class Pcg64:
    def __init__(self, seed: int, stream: int):
        self.state = 0
        self.inc = ((stream << 1) | 1) & MASK128
        self.next_u64()
        self.state = (self.state + (seed & 0xFFFFFFFFFFFFFFFF)) & MASK128
        self.next_u64()

    @classmethod
    def seeded(cls, seed: int) -> "Pcg64":
        return cls(seed, 0)

    def next_u64(self) -> int:
        self.state = (self.state * MULT + self.inc) & MASK128
        rot = self.state >> 122
        xsl = ((self.state >> 64) ^ self.state) & 0xFFFFFFFFFFFFFFFF
        return ((xsl >> rot) | (xsl << (64 - rot) if rot else 0)) & 0xFFFFFFFFFFFFFFFF

    def next_f64(self) -> float:
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def normal(self) -> float:
        while True:
            u1 = self.next_f64()
            if u1 > 1e-12:
                u2 = self.next_f64()
                return math.sqrt(-2.0 * math.log(u1)) * math.cos(2.0 * math.pi * u2)

    def fill_normal(self, n: int, sigma: float) -> np.ndarray:
        s = F(sigma)
        return np.array([F(self.normal()) * s for _ in range(n)], dtype=F)


# ---------------------------------------------------------------------------
# Mirrors of rust/src/factorize/tt.rs
# ---------------------------------------------------------------------------

def mode_dims(dim: int, modes: int) -> list[int]:
    dims, rem = [], dim
    for slots in range(modes, 1, -1):
        target = rem ** (1.0 / slots)
        best, best_gap = 1, float("inf")
        for d in range(1, rem + 1):
            if rem % d == 0 and abs(d - target) < best_gap:
                best, best_gap = d, abs(d - target)
        dims.append(best)
        rem //= best
    dims.append(rem)
    return dims


def permute_w_to_t(w: np.ndarray, m_dims: list[int], n_dims: list[int]) -> np.ndarray:
    d = len(m_dims)
    # (i_1..i_d, j_1..j_d) -> interleaved (i_1, j_1, .., i_d, j_d).
    t = w.reshape(m_dims + n_dims)
    perm = [axis for k in range(d) for axis in (k, d + k)]
    return np.ascontiguousarray(t.transpose(perm))


def permute_t_to_w(t: np.ndarray, m_dims: list[int], n_dims: list[int]) -> np.ndarray:
    d = len(m_dims)
    inter = t.reshape([dim for k in range(d) for dim in (m_dims[k], n_dims[k])])
    perm = [2 * k for k in range(d)] + [2 * k + 1 for k in range(d)]
    m = int(np.prod(m_dims))
    return np.ascontiguousarray(inter.transpose(perm)).reshape(m, int(np.prod(n_dims)))


def rank_for_energy(energies: np.ndarray, tau: float) -> int:
    total = float(energies.sum())
    target = tau * total
    acc = 0.0
    for i, e in enumerate(energies):
        acc += float(e)
        if acc >= target - 1e-12:
            return i + 1
    return len(energies)


def tt_svd(w: np.ndarray, modes: int, energy: float):
    m_dims, n_dims = mode_dims(w.shape[0], modes), mode_dims(w.shape[1], modes)
    g = [m_dims[k] * n_dims[k] for k in range(modes)]
    total_energy = float((w.astype(np.float64) ** 2).sum())
    budget = (1.0 - energy) * total_energy / (modes - 1)

    c = permute_w_to_t(w, m_dims, n_dims).reshape(-1)
    r_prev, cores, margins = 1, [], []
    for k in range(modes - 1):
        rows = r_prev * g[k]
        mat = c.reshape(rows, -1)
        u, s, vt = np.linalg.svd(mat.astype(np.float64), full_matrices=False)
        energies = s * s
        total = float(energies.sum())
        tau_step = max((total - budget) / total, 0.0) if total > 0 else 0.0
        r = max(rank_for_energy(energies, tau_step), 1)
        r = min(r, len(s))
        # Robustness of the pin: the cumulative-energy crossing and the
        # spectral gap at the cut must both be wide, or a Jacobi-vs-LAPACK
        # difference could flip the selected rank between implementations.
        cum = np.cumsum(energies) / total
        lo = cum[r - 2] if r >= 2 else 0.0
        margins.append((tau_step - lo, cum[r - 1] - tau_step, s[r - 1] / s[r] if r < len(s) else np.inf))
        core = u[:, :r].astype(F)
        cores.append(core.reshape(r_prev, m_dims[k], n_dims[k], r))
        c = (np.diag(s[:r]) @ vt[:r]).astype(F).reshape(-1)
        r_prev = r
    cores.append(c.reshape(r_prev, m_dims[-1], n_dims[-1], 1))
    return m_dims, n_dims, cores, margins


def tt_reconstruct(cores, m_dims, n_dims) -> np.ndarray:
    acc = np.array([[1.0]], dtype=np.float64)
    p = 1
    for c in cores:
        r_in, m, n, r_out = c.shape
        acc = (acc.reshape(p, r_in) @ c.astype(np.float64).reshape(r_in, -1)).reshape(
            p * m * n, r_out
        )
        p *= m * n
    t = acc.reshape([m_dims[k] * n_dims[k] for k in range(len(m_dims))])
    return permute_t_to_w(t.astype(F), m_dims, n_dims)


def main() -> None:
    rng = Pcg64.seeded(0)
    w = np.zeros((M, N), dtype=F)
    for l in range(TERMS):
        a = rng.fill_normal(64, 1.0).reshape(8, 8)
        b = rng.fill_normal(64, 1.0).reshape(8, 8)
        w += F(0.5**l) * np.kron(a, b)

    m_dims, n_dims, cores, margins = tt_svd(w, MODES, ENERGY)
    ranks = [c.shape[3] for c in cores[:-1]]
    for lo, hi, gap in margins:
        assert lo > 1e-3 and hi > 1e-3, f"fragile energy crossing: {margins}"
        assert gap > 1.2, f"fragile spectral gap at the cut: {margins}"

    rec = tt_reconstruct(cores, m_dims, n_dims)
    err = float(np.linalg.norm((w - rec).astype(np.float64)) / np.linalg.norm(w.astype(np.float64)))
    bound = math.sqrt(1.0 - ENERGY)
    assert err <= bound + 1e-6, f"recon err {err} above sqrt(1-tau) {bound}"

    probes = [float(rec[0, c]) for c in range(0, 64, 8)]
    n_params = sum(c.size for c in cores)

    print(f"// seed-0 {M}x{N} Kronecker-sum weight, modes={MODES}, energy={ENERGY}")
    print(f"// margins (lo, hi, gap) per unfolding: {margins}")
    print(f"const TT_GOLDEN_RANKS: &[usize] = &{ranks};")
    print(f"const TT_GOLDEN_N_PARAMS: usize = {n_params};")
    print(f"const TT_GOLDEN_RECON_ERR: f64 = {err:.6};")
    print("#[rustfmt::skip]")
    row = ", ".join(f"{p:.6}" for p in probes)
    print(f"const TT_GOLDEN_ROW0_PROBES: [f32; 8] = [{row}];")


if __name__ == "__main__":
    main()
