"""Derive the pinned loss curves for tests/golden_native_train.rs.

Independent numpy/float32 reimplementation of the native training path
(`rust/src/backend/{native,grad}.rs`): PCG64 streams, the synthetic data
generators, the deterministic inits, forward, backward and Adam — with the
same accumulation *order* as the Rust code (GEMMs accumulate over k
sequentially per output element; reductions are fixed-order sequential
sums), so the two implementations agree to float32 transcendental-ulp noise
(~1e-6 on these losses by an injected-noise experiment — well inside the
2e-3 tolerance of tests/golden_native_train.rs; keep the two in sync).

Validation: before deriving anything, the script regenerates the pinned
constants of tests/golden_data.rs (polarity tokens, blobs probes) from its
own PCG64 + generators; a mismatch aborts. That cross-checks the entire
random-stream plumbing against the Rust implementation, which itself was
cross-checked against numpy's PCG64 in PR 2.

Usage:
    python3 python/tools/derive_native_train_golden.py          # goldens
    python3 python/tools/derive_native_train_golden.py --learn  # also run the
        300-step learning sanity check backing integration_train_native.rs
"""

from __future__ import annotations

import math
import sys

import numpy as np

F = np.float32
MASK128 = (1 << 128) - 1
MULT = 0x2360ED051FC65DA44385DF649FCCF645


# ---------------------------------------------------------------------------
# PCG64 (XSL-RR 128/64) — mirror of rust/src/util/rng.rs
# ---------------------------------------------------------------------------

class Pcg64:
    def __init__(self, seed: int, stream: int):
        self.state = 0
        self.inc = ((stream << 1) | 1) & MASK128
        self.next_u64()
        self.state = (self.state + (seed & 0xFFFFFFFFFFFFFFFF)) & MASK128
        self.next_u64()

    @classmethod
    def seeded(cls, seed: int) -> "Pcg64":
        return cls(seed, 0)

    def next_u64(self) -> int:
        self.state = (self.state * MULT + self.inc) & MASK128
        rot = self.state >> 122
        xsl = ((self.state >> 64) ^ self.state) & 0xFFFFFFFFFFFFFFFF
        return ((xsl >> rot) | (xsl << (64 - rot) if rot else 0)) & 0xFFFFFFFFFFFFFFFF

    def next_f64(self) -> float:
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def next_f32(self) -> np.float32:
        return F(self.next_f64())

    def below(self, n: int) -> int:
        zone = 0xFFFFFFFFFFFFFFFF - (0xFFFFFFFFFFFFFFFF % n)
        while True:
            v = self.next_u64()
            if v < zone:
                return v % n

    def normal(self) -> float:
        while True:
            u1 = self.next_f64()
            if u1 > 1e-12:
                u2 = self.next_f64()
                return math.sqrt(-2.0 * math.log(u1)) * math.cos(2.0 * math.pi * u2)

    def normal_f32(self) -> np.float32:
        return F(self.normal())

    def fill_normal(self, n: int, sigma: float) -> np.ndarray:
        s = F(sigma)
        return np.array([self.normal_f32() * s for _ in range(n)], dtype=F)

    def shuffle(self, xs: list) -> None:
        for i in range(len(xs) - 1, 0, -1):
            j = self.below(i + 1)
            xs[i], xs[j] = xs[j], xs[i]


# ---------------------------------------------------------------------------
# Synthetic tasks — mirrors of rust/src/data/{text,image}.rs
# ---------------------------------------------------------------------------

VOCAB_SIZE = 512
CLS, SEP = 1, 2
WORDS = 11  # LABEL_BASE(3) + NUM_LABELS(8)
TRAIN_STREAM = 1
HW = 28


def _rng_for_text(seed: int, index: int) -> Pcg64:
    mixed = (seed ^ ((index * 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF)) & 0xFFFFFFFFFFFFFFFF
    return Pcg64(mixed, TRAIN_STREAM)


def polarity_example(seq: int, task_seed: int, index: int):
    """PolarityTask::example(Train, index)."""
    rng = _rng_for_text(task_seed ^ 0x70, index)
    label = rng.below(2)
    maj = 2 + rng.below(5)
    minor = rng.below(maj)
    n_pos, n_neg = (maj, minor) if label == 1 else (minor, maj)
    filler_base = WORDS + 40
    filler_count = VOCAB_SIZE - filler_base
    toks = [filler_base + rng.below(filler_count) for _ in range(seq)]
    toks[0] = CLS
    positions = list(range(1, seq))
    rng.shuffle(positions)
    for k_i, pos in enumerate(positions[: n_pos + n_neg]):
        if k_i < n_pos:
            toks[pos] = WORDS + rng.below(20)
        else:
            toks[pos] = WORDS + 20 + rng.below(20)
    return toks, label


def _rng_for_image(seed: int, index: int) -> Pcg64:
    mixed = (seed ^ ((index * 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF)) & 0xFFFFFFFFFFFFFFFF
    return Pcg64(mixed, TRAIN_STREAM + 10)


def blobs_example(task_seed: int, index: int):
    """BlobsTask::example(Train, index)."""
    rng = _rng_for_image(task_seed ^ 0x81, index)
    label = rng.below(4)
    img = np.zeros(HW * HW, dtype=F)

    def bump(cx: float, cy: float, sigma: float, amp: float):
        a = F(amp)
        for y in range(HW):
            for x in range(HW):
                d2 = (x - cx) ** 2 + (y - cy) ** 2
                img[y * HW + x] += a * F(math.exp(-d2 / (2.0 * sigma * sigma)))

    qx = 7.0 if label % 2 == 0 else 21.0
    qy = 7.0 if label < 2 else 21.0
    j1 = (rng.next_f64() - 0.5) * 6.0
    j2 = (rng.next_f64() - 0.5) * 6.0
    sig = 2.0 + rng.next_f64() * 1.5
    bump(qx + j1, qy + j2, sig, 0.9)
    bump(rng.next_f64() * HW, rng.next_f64() * HW, 2.0, 0.35)
    s = F(0.05)
    for i in range(HW * HW):
        img[i] = min(max(img[i] + rng.normal_f32() * s, F(0.0)), F(1.0))
    return img, label


# ---------------------------------------------------------------------------
# Inits — mirrors of init_text_params / init_image_params
# ---------------------------------------------------------------------------

def glorot(rng: Pcg64, k: int, n: int) -> np.ndarray:
    limit = F(math.sqrt(6.0 / (k + n)))
    out = np.empty(k * n, dtype=F)
    two, one = F(2.0), F(1.0)
    for i in range(k * n):
        out[i] = (rng.next_f32() * two - one) * limit
    return out.reshape(k, n)


def init_text_params(cfg: dict, seed: int) -> dict:
    rng = Pcg64(seed, 7)
    p = {}
    v, s, d, ff, classes, layers = (
        cfg["vocab"], cfg["seq"], cfg["d"], cfg["ff"], cfg["classes"], cfg["layers"],
    )
    p["embed/table"] = rng.fill_normal(v * d, 0.02).reshape(v, d)
    p["pos/table"] = rng.fill_normal(s * d, 0.02).reshape(s, d)
    for i in range(layers):
        for proj in ["q", "k", "v", "o"]:
            p[f"block{i}/attn/{proj}/w"] = glorot(rng, d, d)
            p[f"block{i}/attn/{proj}/bias"] = np.zeros(d, dtype=F)
        for ln in ["ln1", "ln2"]:
            p[f"block{i}/{ln}/g"] = np.ones(d, dtype=F)
            p[f"block{i}/{ln}/bias"] = np.zeros(d, dtype=F)
        p[f"block{i}/fc1/w"] = glorot(rng, d, ff)
        p[f"block{i}/fc1/bias"] = np.zeros(ff, dtype=F)
        p[f"block{i}/fc2/w"] = glorot(rng, ff, d)
        p[f"block{i}/fc2/bias"] = np.zeros(d, dtype=F)
    p["head/w"] = glorot(rng, d, classes)
    p["head/bias"] = np.zeros(classes, dtype=F)
    p["ln_f/g"] = np.ones(d, dtype=F)
    p["ln_f/bias"] = np.zeros(d, dtype=F)
    return p


def uniform4(rng: Pcg64, shape, fan_in: int, fan_out: int) -> np.ndarray:
    limit = F(math.sqrt(6.0 / (fan_in + fan_out)))
    n = int(np.prod(shape))
    out = np.empty(n, dtype=F)
    two, one = F(2.0), F(1.0)
    for i in range(n):
        out[i] = (rng.next_f32() * two - one) * limit
    return out.reshape(shape)


def init_image_params(cfg: dict, seed: int) -> dict:
    rng = Pcg64(seed, 8)
    hw, ch, classes, c1, c2, fc = (
        cfg["hw"], cfg["ch"], cfg["classes"], cfg["c1"], cfg["c2"], cfg["fc"],
    )
    flat = (hw // 4) * (hw // 4) * c2
    rf = 9
    p = {}
    p["conv1/w"] = uniform4(rng, (3, 3, ch, c1), rf * ch, rf * c1)
    p["conv1/bias"] = np.zeros(c1, dtype=F)
    p["conv2/w"] = uniform4(rng, (3, 3, c1, c2), rf * c1, rf * c2)
    p["conv2/bias"] = np.zeros(c2, dtype=F)
    p["fc1/w"] = uniform4(rng, (flat, fc), flat, fc)
    p["fc1/bias"] = np.zeros(fc, dtype=F)
    p["fc2/w"] = uniform4(rng, (fc, classes), fc, classes)
    p["fc2/bias"] = np.zeros(classes, dtype=F)
    return p


# ---------------------------------------------------------------------------
# f32 primitives with Rust-matched accumulation order
# ---------------------------------------------------------------------------

def mm(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """(m,k)@(k,n) accumulating over k in order, like matmul_into."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    out = np.zeros((m, n), dtype=F)
    for p in range(k):
        out += a[:, p : p + 1] * b[p : p + 1, :]
    return out


def seq_sum(x: np.ndarray) -> np.ndarray:
    """Sequential sum over the last axis (Rust row-order f32 accumulation)."""
    acc = np.zeros(x.shape[:-1], dtype=F)
    for j in range(x.shape[-1]):
        acc = acc + x[..., j]
    return acc


def apply_linear(params: dict, prefix: str, x: np.ndarray) -> np.ndarray:
    if f"{prefix}/w" in params:
        w = params[f"{prefix}/w"]
        w2 = w.reshape(-1, w.shape[-1])
        y = mm(x, w2)
    else:
        a = params[f"{prefix}/a"].reshape(-1, params[f"{prefix}/a"].shape[-1])
        b = params[f"{prefix}/b"].reshape(-1, params[f"{prefix}/b"].shape[-1])
        y = mm(mm(x, a), b)
    bias = params.get(f"{prefix}/bias")
    if bias is not None:
        y = y + bias
    return y


LN_EPS = F(1e-5)


def layernorm(params: dict, prefix: str, x: np.ndarray) -> np.ndarray:
    d = x.shape[-1]
    g, bias = params[f"{prefix}/g"], params[f"{prefix}/bias"]
    mean = (seq_sum(x) / F(d))[:, None]
    var = (seq_sum((x - mean) * (x - mean)) / F(d))[:, None]
    inv = F(1.0) / np.sqrt(var + LN_EPS)
    return (x - mean) * inv * g + bias


def gelu(x: np.ndarray) -> np.ndarray:
    c, a, half, one = F(0.7978846), F(0.044715), F(0.5), F(1.0)
    t = c * (x + a * x * x * x)
    return half * x * (one + np.tanh(t))


def softmax_rows(x: np.ndarray) -> np.ndarray:
    mx = np.max(x, axis=-1, keepdims=True)
    e = np.exp(x - mx)
    s = seq_sum(e)[..., None]
    return e * (F(1.0) / s)


def embed_fwd(params: dict, tokens: np.ndarray) -> np.ndarray:
    b, s = tokens.shape
    table, pos = params["embed/table"], params["pos/table"]
    d = table.shape[1]
    x = np.empty((b * s, d), dtype=F)
    for bi in range(b):
        for si in range(s):
            x[bi * s + si] = table[tokens[bi, si]] + pos[si]
    return x


def attention_fwd(params: dict, prefix: str, b, s, d, heads, causal, x):
    dk = d // heads
    q = apply_linear(params, f"{prefix}/q", x)
    k = apply_linear(params, f"{prefix}/k", x)
    v = apply_linear(params, f"{prefix}/v", x)
    scale = F(1.0 / math.sqrt(dk))
    ctx = np.zeros((b * s, d), dtype=F)
    probs = np.zeros((b * heads, s, s), dtype=F)
    for bi in range(b):
        rows = slice(bi * s, (bi + 1) * s)
        for h in range(heads):
            cols = slice(h * dk, (h + 1) * dk)
            qh, kh, vh = q[rows, cols], k[rows, cols], v[rows, cols]
            scores = mm(qh, kh.T.copy()) * scale
            if causal:
                for i in range(s):
                    scores[i, i + 1 :] = F(-1e9)
            p = softmax_rows(scores)
            probs[bi * heads + h] = p
            ctx[rows, cols] = mm(p, vh)
    out = apply_linear(params, f"{prefix}/o", ctx)
    return {"q": q, "k": k, "v": v, "probs": probs, "ctx": ctx}, out


def block_fwd(params: dict, prefix: str, b, s, d, heads, causal, x):
    tape = {"x_in": x.copy()}
    xn1 = layernorm(params, f"{prefix}/ln1", x)
    tape["xn1"] = xn1
    tape["attn"], attn_out = attention_fwd(params, f"{prefix}/attn", b, s, d, heads, causal, xn1)
    x = x + attn_out
    tape["x_mid"] = x.copy()
    xn2 = layernorm(params, f"{prefix}/ln2", x)
    tape["xn2"] = xn2
    h_pre = apply_linear(params, f"{prefix}/fc1", xn2)
    tape["h_pre"] = h_pre
    h_act = gelu(h_pre)
    tape["h_act"] = h_act
    x = x + apply_linear(params, f"{prefix}/fc2", h_act)
    return tape, x


def num_blocks(params: dict) -> int:
    n = 0
    while f"block{n}/ln1/g" in params:
        n += 1
    return n


def trunk_fwd(params: dict, tokens: np.ndarray, heads: int, causal: bool):
    b, s = tokens.shape
    x = embed_fwd(params, tokens)
    d = x.shape[1]
    blocks = []
    for i in range(num_blocks(params)):
        tape, x = block_fwd(params, f"block{i}", b, s, d, heads, causal, x)
        blocks.append(tape)
    pre = x.copy()
    out = layernorm(params, "ln_f", x)
    return {"d": d, "blocks": blocks, "x_pre_lnf": pre, "x_out": out}


def softmax_xent(logits: np.ndarray, labels: np.ndarray):
    rows, width = logits.shape
    inv_rows = F(1.0) / F(rows)
    d = np.zeros_like(logits)
    total = F(0.0)
    for i in range(rows):
        row = logits[i]
        mx = np.max(row)
        e = np.exp(row - mx)
        ssum = F(0.0)
        for j in range(width):
            ssum = ssum + e[j]
        total = total + (mx + np.log(ssum) - row[labels[i]])
        inv = F(1.0) / ssum
        p = e * inv
        onehot = np.zeros(width, dtype=F)
        onehot[labels[i]] = F(1.0)
        d[i] = (p - onehot) * inv_rows
    return total * inv_rows, d


# ---------------------------------------------------------------------------
# Backward — mirror of rust/src/backend/grad.rs
# ---------------------------------------------------------------------------

def linear_bwd(params, prefix, x, dy, grads):
    if f"{prefix}/w" in params:
        w = params[f"{prefix}/w"]
        w2 = w.reshape(-1, w.shape[-1])
        grads[f"{prefix}/w"] = mm(x.T.copy(), dy).reshape(w.shape)
        dx = mm(dy, w2.T.copy())
    else:
        a4, b4 = params[f"{prefix}/a"], params[f"{prefix}/b"]
        a = a4.reshape(-1, a4.shape[-1])
        b = b4.reshape(-1, b4.shape[-1])
        h = mm(x, a)
        grads[f"{prefix}/b"] = mm(h.T.copy(), dy).reshape(b4.shape)
        dh = mm(dy, b.T.copy())
        grads[f"{prefix}/a"] = mm(x.T.copy(), dh).reshape(a4.shape)
        dx = mm(dh, a.T.copy())
    if f"{prefix}/bias" in params:
        db = np.zeros(dy.shape[1], dtype=F)
        for r in range(dy.shape[0]):
            db += dy[r]
        grads[f"{prefix}/bias"] = db
    return dx


def layernorm_bwd(params, prefix, x_pre, dy, grads):
    d = x_pre.shape[-1]
    g = params[f"{prefix}/g"]
    inv_d = F(1.0 / d)
    mean = (seq_sum(x_pre) / F(d))[:, None]
    var = (seq_sum((x_pre - mean) * (x_pre - mean)) / F(d))[:, None]
    inv = F(1.0) / np.sqrt(var + LN_EPS)
    xhat = (x_pre - mean) * inv
    dxhat = dy * g
    dgain = np.zeros(d, dtype=F)
    dbias = np.zeros(d, dtype=F)
    for r in range(dy.shape[0]):
        dgain += dy[r] * xhat[r]
        dbias += dy[r]
    m1 = (seq_sum(dxhat) * inv_d)[:, None]
    m2 = (seq_sum(dxhat * xhat) * inv_d)[:, None]
    dx = (dxhat - m1 - xhat * m2) * inv
    grads[f"{prefix}/g"] = grads.get(f"{prefix}/g", np.zeros(d, dtype=F)) + dgain
    grads[f"{prefix}/bias"] = grads.get(f"{prefix}/bias", np.zeros(d, dtype=F)) + dbias
    return dx


def gelu_bwd(h_pre, dy):
    c, a, half, one, three = F(0.7978846), F(0.044715), F(0.5), F(1.0), F(3.0)
    u = c * (h_pre + a * h_pre * h_pre * h_pre)
    t = np.tanh(u)
    du = c * (one + three * a * h_pre * h_pre)
    return dy * (half * (one + t) + half * h_pre * (one - t * t) * du)


def attention_bwd(params, prefix, tape, b, s, d, heads, x, dout, grads):
    dk = d // heads
    scale = F(1.0 / math.sqrt(dk))
    dctx = linear_bwd(params, f"{prefix}/o", tape["ctx"], dout, grads)
    dq = np.zeros((b * s, d), dtype=F)
    dkm = np.zeros((b * s, d), dtype=F)
    dv = np.zeros((b * s, d), dtype=F)
    for bi in range(b):
        rows = slice(bi * s, (bi + 1) * s)
        for h in range(heads):
            cols = slice(h * dk, (h + 1) * dk)
            qh, kh, vh = tape["q"][rows, cols], tape["k"][rows, cols], tape["v"][rows, cols]
            dch = dctx[rows, cols]
            ph = tape["probs"][bi * heads + h]
            dprobs = mm(dch, vh.T.copy())
            dvh = mm(ph.T.copy(), dch)
            dscores = np.zeros((s, s), dtype=F)
            for i in range(s):
                dot = F(0.0)
                for j in range(s):
                    dot = dot + ph[i, j] * dprobs[i, j]
                dscores[i] = ph[i] * (dprobs[i] - dot) * scale
            dqh = mm(dscores, kh)
            dkh = mm(dscores.T.copy(), qh)
            dq[rows, cols] = dqh
            dkm[rows, cols] = dkh
            dv[rows, cols] = dvh
    dx = linear_bwd(params, f"{prefix}/q", x, dq, grads)
    dx = dx + linear_bwd(params, f"{prefix}/k", x, dkm, grads)
    dx = dx + linear_bwd(params, f"{prefix}/v", x, dv, grads)
    return dx


def block_bwd(params, prefix, tape, b, s, d, heads, dx_out, grads):
    dh_act = linear_bwd(params, f"{prefix}/fc2", tape["h_act"], dx_out, grads)
    dh_pre = gelu_bwd(tape["h_pre"], dh_act)
    dxn2 = linear_bwd(params, f"{prefix}/fc1", tape["xn2"], dh_pre, grads)
    dln2 = layernorm_bwd(params, f"{prefix}/ln2", tape["x_mid"], dxn2, grads)
    dmid = dx_out + dln2
    dxn1 = attention_bwd(
        params, f"{prefix}/attn", tape["attn"], b, s, d, heads, tape["xn1"], dmid, grads
    )
    dln1 = layernorm_bwd(params, f"{prefix}/ln1", tape["x_in"], dxn1, grads)
    return dmid + dln1


def trunk_bwd(params, tokens, tape, heads, dx_out, grads):
    b, s = tokens.shape
    d = tape["d"]
    dx = layernorm_bwd(params, "ln_f", tape["x_pre_lnf"], dx_out, grads)
    for i in reversed(range(len(tape["blocks"]))):
        dx = block_bwd(params, f"block{i}", tape["blocks"][i], b, s, d, heads, dx, grads)
    table, pos = params["embed/table"], params["pos/table"]
    dtable = np.zeros_like(table)
    dpos = np.zeros_like(pos)
    for bi in range(b):
        for si in range(s):
            row = dx[bi * s + si]
            dtable[tokens[bi, si]] += row
            dpos[si] += row
    grads["embed/table"] = dtable
    grads["pos/table"] = dpos


def classifier_loss_grads(params, tokens, labels, heads):
    b, s = tokens.shape
    tape = trunk_fwd(params, tokens, heads, causal=False)
    d = tape["d"]
    inv_s = F(1.0 / s)
    pooled = np.zeros((b, d), dtype=F)
    for bi in range(b):
        for si in range(s):
            pooled[bi] += tape["x_out"][bi * s + si]
        pooled[bi] *= inv_s
    logits = apply_linear(params, "head", pooled)
    loss, dlogits = softmax_xent(logits, labels)
    grads = {}
    dpooled = linear_bwd(params, "head", pooled, dlogits, grads)
    dx = np.zeros((b * s, d), dtype=F)
    for bi in range(b):
        for si in range(s):
            dx[bi * s + si] = dpooled[bi] * inv_s
    trunk_bwd(params, tokens, tape, heads, dx, grads)
    return loss, grads


# ---------------------------------------------------------------------------
# Image model (im2col conv path)
# ---------------------------------------------------------------------------

def im2col(x, b, h, w, c, kh, kw):
    ph, pw = kh // 2, kw // 2
    x4 = x.reshape(b, h, w, c)
    out = np.zeros((b, h, w, kh, kw, c), dtype=F)
    for ky in range(kh):
        sy0, sy1 = max(0, ph - ky), min(h, h + ph - ky)
        dy0 = sy0 + ky - ph
        for kx in range(kw):
            sx0, sx1 = max(0, pw - kx), min(w, w + pw - kx)
            dx0 = sx0 + kx - pw
            out[:, sy0:sy1, sx0:sx1, ky, kx, :] = x4[
                :, dy0 : dy0 + (sy1 - sy0), dx0 : dx0 + (sx1 - sx0), :
            ]
    return out.reshape(b * h * w, kh * kw * c)


def col2im(dcols, b, h, w, c, kh, kw):
    ph, pw = kh // 2, kw // 2
    d6 = dcols.reshape(b, h, w, kh, kw, c)
    dx = np.zeros((b, h, w, c), dtype=F)
    for ky in range(kh):
        sy0, sy1 = max(0, ph - ky), min(h, h + ph - ky)
        dy0 = sy0 + ky - ph
        for kx in range(kw):
            sx0, sx1 = max(0, pw - kx), min(w, w + pw - kx)
            dx0 = sx0 + kx - pw
            dx[:, dy0 : dy0 + (sy1 - sy0), dx0 : dx0 + (sx1 - sx0), :] += d6[
                :, sy0:sy1, sx0:sx1, ky, kx, :
            ]
    return dx.reshape(b * h * w * c)


def maxpool2_idx(y, b, h, w, c):
    oh, ow = h // 2, w // 2
    y4 = y.reshape(b, h, w, c)
    cand = np.stack(
        [
            y4[:, 0::2, 0::2, :],
            y4[:, 0::2, 1::2, :],
            y4[:, 1::2, 0::2, :],
            y4[:, 1::2, 1::2, :],
        ],
        axis=0,
    )
    pick = np.argmax(cand, axis=0)  # first max — same tie-break as Rust
    out = np.take_along_axis(cand, pick[None], axis=0)[0]
    # Flat source index in the (b, h, w, c) layout.
    bi, yi, xi, ci = np.meshgrid(
        np.arange(b), np.arange(oh), np.arange(ow), np.arange(c), indexing="ij"
    )
    sy = 2 * yi + (pick // 2)
    sx = 2 * xi + (pick % 2)
    idx = ((bi * h + sy) * w + sx) * c + ci
    return oh, ow, out.reshape(b * oh * ow, c).reshape(-1, c), idx.reshape(-1)


def image_loss_grads(params, pixels, labels):
    b, h, w, c = pixels.shape
    cur = pixels.reshape(b * h * w, c).astype(F).reshape(-1)
    tapes = []
    for conv in ["conv1", "conv2"]:
        wkey = f"{conv}/w" if f"{conv}/w" in params else f"{conv}/a"
        kh, kw, cin = params[wkey].shape[:3]
        cols = im2col(cur, b, h, w, c, kh, kw)
        y_pre = apply_linear(params, conv, cols)
        cout = y_pre.shape[1]
        y_act = np.maximum(y_pre, F(0.0))
        oh, ow, pooled, pool_idx = maxpool2_idx(y_act.reshape(-1), b, h, w, cout)
        tapes.append(
            {"cols": cols, "y_pre": y_pre, "pool_idx": pool_idx, "dims": (h, w, c, cout, kh, kw)}
        )
        cur = pooled.reshape(-1)
        h, w, c = oh, ow, cout
    flat = h * w * c
    flat_in = cur.reshape(b, flat)
    f1_pre = apply_linear(params, "fc1", flat_in)
    f1_act = np.maximum(f1_pre, F(0.0))
    logits = apply_linear(params, "fc2", f1_act)
    loss, dlogits = softmax_xent(logits, labels)

    grads = {}
    df1_act = linear_bwd(params, "fc2", f1_act, dlogits, grads)
    df1_pre = np.where(f1_pre > 0, df1_act, F(0.0))
    dcur = linear_bwd(params, "fc1", flat_in, df1_pre, grads).reshape(-1)
    for conv, tape in reversed(list(zip(["conv1", "conv2"], tapes))):
        th, tw, tc, cout, kh, kw = tape["dims"]
        dy_act = np.zeros(b * th * tw * cout, dtype=F)
        np.add.at(dy_act, tape["pool_idx"], dcur)
        dy_pre = np.where(
            tape["y_pre"].reshape(-1) > 0, dy_act, F(0.0)
        ).reshape(b * th * tw, cout)
        dcols = linear_bwd(params, conv, tape["cols"], dy_pre, grads)
        dcur = col2im(dcols, b, th, tw, tc, kh, kw)
    return loss, grads


# ---------------------------------------------------------------------------
# Adam — mirror of grad::adam_step
# ---------------------------------------------------------------------------

LR, B1, B2, EPS = F(1e-3), F(0.9), F(0.999), F(1e-8)


def adam_step(params, m, v, grads, step):
    bc1 = F(1.0) - B1 ** F(step)
    bc2 = F(1.0) - B2 ** F(step)
    one = F(1.0)
    for name in params:
        g = grads.get(name, np.zeros_like(params[name])).reshape(params[name].shape)
        m[name] = B1 * m[name] + (one - B1) * g
        v[name] = B2 * v[name] + (one - B2) * g * g
        mhat = m[name] / bc1
        vhat = v[name] / bc2
        params[name] = params[name] - LR * mhat / (np.sqrt(vhat) + EPS)


# ---------------------------------------------------------------------------
# Validation against the PR-2 pinned golden data
# ---------------------------------------------------------------------------

POLARITY_TOKENS = [
    1, 111, 66, 380, 475, 64, 68, 200, 402, 57, 449, 389, 219, 413, 361, 108,
    173, 142, 45, 337, 420, 252, 395, 125, 248, 178, 490, 56, 122, 157, 18, 178,
    413, 305, 310, 403, 185, 152, 321, 472, 480, 328, 158, 208, 117, 323, 510, 413,
    490, 271, 90, 137, 329, 253, 499, 189, 295, 125, 190, 54, 432, 337, 48, 507,
]
PIX_IDX = [0, 49, 98, 147, 196, 245, 294, 343, 392, 441, 490, 539, 588, 637, 686, 735]
BLOBS_PROBES = [
    0.057342, 0.0645856, 0.0813607, 0.0247114, 0.0428923, 0.00321283, 0.0, 0.0,
    0.0059928, 0.104664, 0.00801224, 0.0141336, 0.0, 0.893152, 0.0432883, 0.269171,
]
BLOBS_SUM = 55.678268


def validate_streams():
    toks, label = polarity_example(64, 0, 0)
    assert label == 0, label
    assert toks == POLARITY_TOKENS, "polarity stream mismatch"
    img, label = blobs_example(0, 0)
    assert label == 3, label
    for i, want in zip(PIX_IDX, BLOBS_PROBES):
        assert abs(float(img[i]) - want) < 1e-3, (i, float(img[i]), want)
    assert abs(float(np.sum(img.astype(np.float64))) - BLOBS_SUM) < 0.2
    print("stream validation OK (polarity tokens + blobs probes reproduce golden_data.rs)")


# ---------------------------------------------------------------------------
# Golden derivation
# ---------------------------------------------------------------------------

TEXT_CFG = {"vocab": 512, "seq": 64, "d": 32, "heads": 4, "layers": 1, "ff": 64, "classes": 4}
IMAGE_CFG = {"hw": 28, "ch": 1, "classes": 4, "c1": 4, "c2": 8, "fc": 16}


def derive_text(steps=10, batch=8, init_seed=1, task_seed=0):
    params = init_text_params(TEXT_CFG, init_seed)
    m = {k: np.zeros_like(t) for k, t in params.items()}
    v = {k: np.zeros_like(t) for k, t in params.items()}
    losses = []
    for step in range(1, steps + 1):
        start = (step - 1) * batch
        toks = np.array(
            [polarity_example(64, task_seed, start + i)[0] for i in range(batch)], dtype=np.int64
        )
        labels = np.array(
            [polarity_example(64, task_seed, start + i)[1] for i in range(batch)], dtype=np.int64
        )
        loss, grads = classifier_loss_grads(params, toks, labels, TEXT_CFG["heads"])
        adam_step(params, m, v, grads, step)
        losses.append(float(loss))
        print(f"  text step {step}: loss {loss:.6f}")
    return losses


def derive_image(steps=6, batch=4, init_seed=2, task_seed=0):
    params = init_image_params(IMAGE_CFG, init_seed)
    m = {k: np.zeros_like(t) for k, t in params.items()}
    v = {k: np.zeros_like(t) for k, t in params.items()}
    losses = []
    for step in range(1, steps + 1):
        start = (step - 1) * batch
        exs = [blobs_example(task_seed, start + i) for i in range(batch)]
        pixels = np.stack([e[0] for e in exs]).reshape(batch, HW, HW, 1).astype(F)
        labels = np.array([e[1] for e in exs], dtype=np.int64)
        loss, grads = image_loss_grads(params, pixels, labels)
        adam_step(params, m, v, grads, step)
        losses.append(float(loss))
        print(f"  image step {step}: loss {loss:.6f}")
    return losses


def fmt(losses):
    return ", ".join(f"{l:.6}" for l in losses)


def learning_check():
    """Fast (BLAS matmul) sanity run backing the thresholds in
    tests/integration_train_native.rs: by-design LED-r50 text model, 300
    steps on polarity, then held-out accuracy. Not bit-matched to Rust —
    dynamics-level validation only."""
    global mm, seq_sum
    mm_exact, seq_exact = mm, seq_sum
    mm = lambda a, b: (a @ b).astype(F)  # noqa: E731
    seq_sum = lambda x: np.sum(x, axis=-1, dtype=F)  # noqa: E731
    try:
        params = init_text_params(TEXT_CFG, 42)
        # LED-r50 by design: SVD-factorize every layer the Eq.-1 gate accepts
        # (attn 32x32 -> r8, fc1/fc2 -> r8; head 32x4 rejected).
        for prefix, r in [
            ("block0/attn/q", 8), ("block0/attn/k", 8), ("block0/attn/v", 8),
            ("block0/attn/o", 8), ("block0/fc1", 8), ("block0/fc2", 8),
        ]:
            w = params.pop(f"{prefix}/w")
            u, s, vt = np.linalg.svd(w.astype(np.float64), full_matrices=False)
            params[f"{prefix}/a"] = (u[:, :r] * s[:r]).astype(F)
            params[f"{prefix}/b"] = vt[:r].astype(F)
        m = {k: np.zeros_like(t) for k, t in params.items()}
        v = {k: np.zeros_like(t) for k, t in params.items()}
        losses = []
        for step in range(1, 301):
            start = (step - 1) * 8
            exs = [polarity_example(64, 0, start + i) for i in range(8)]
            toks = np.array([e[0] for e in exs], dtype=np.int64)
            labels = np.array([e[1] for e in exs], dtype=np.int64)
            loss, grads = classifier_loss_grads(params, toks, labels, 4)
            adam_step(params, m, v, grads, step)
            losses.append(float(loss))
        early = sum(losses[:10]) / 10
        late = sum(losses[-20:]) / 20
        # Eval split (stream 2) accuracy.
        correct = 0
        for i in range(128):
            rng_toks, label = eval_polarity_example(64, 0, i)
            tape = trunk_fwd(params, np.array([rng_toks], dtype=np.int64), 4, False)
            pooled = np.mean(tape["x_out"], axis=0, dtype=F)[None, :]
            logits = apply_linear(params, "head", pooled)
            if int(np.argmax(logits[0, :2])) == label:
                correct += 1
        print(f"learning check: early loss {early:.4f} late {late:.4f} "
              f"eval acc {correct}/128 = {correct / 128:.3f}")
    finally:
        mm, seq_sum = mm_exact, seq_exact


def eval_polarity_example(seq, task_seed, index):
    """PolarityTask::example(Eval, index) — stream 2."""
    mixed = (
        (task_seed ^ 0x70) ^ ((index * 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF)
    ) & 0xFFFFFFFFFFFFFFFF
    rng = Pcg64(mixed, 2)
    label = rng.below(2)
    maj = 2 + rng.below(5)
    minor = rng.below(maj)
    n_pos, n_neg = (maj, minor) if label == 1 else (minor, maj)
    filler_base = WORDS + 40
    toks = [filler_base + rng.below(VOCAB_SIZE - filler_base) for _ in range(seq)]
    toks[0] = CLS
    positions = list(range(1, seq))
    rng.shuffle(positions)
    for k_i, pos in enumerate(positions[: n_pos + n_neg]):
        toks[pos] = (WORDS + rng.below(20)) if k_i < n_pos else (WORDS + 20 + rng.below(20))
    return toks, label


if __name__ == "__main__":
    validate_streams()
    print("deriving text golden (polarity, dense d=32, 10 steps)...")
    text = derive_text()
    print("deriving image golden (blobs, dense c1=4/c2=8, 6 steps)...")
    image = derive_image()
    print()
    print(f"const TEXT_LOSSES: [f32; {len(text)}] = [{fmt(text)}];")
    print(f"const IMAGE_LOSSES: [f32; {len(image)}] = [{fmt(image)}];")
    if "--learn" in sys.argv:
        learning_check()
