#!/usr/bin/env python3
"""Run the hermetic benches and persist their BENCH_* JSON lines.

Every perf-relevant bench prints a machine-readable marker line::

    BENCH_KERNELS {...}
    BENCH_NATIVE_DECODE {...}
    BENCH_NATIVE_SERVING {...}
    BENCH_NATIVE_TRAIN {...}

This tool runs ``cargo bench --bench <name>`` for each requested bench,
scrapes those lines, and appends one run record per marker to
``BENCH_<MARKER>.json`` at the repo root::

    {"runs": [{"ts": ..., "git": ..., "bench": ..., "data": {...}}, ...]}

so the perf trajectory accumulates across commits/CI runs instead of
evaporating in build logs. Wired into CI as a non-gating step.

Usage:
    python3 python/tools/collect_bench.py            # default bench set
    python3 python/tools/collect_bench.py --quick    # small env-scaled run
    python3 python/tools/collect_bench.py --benches kernel_speedup
"""

from __future__ import annotations

import argparse
import datetime
import json
import os
import re
import subprocess
import sys

DEFAULT_BENCHES = [
    "kernel_speedup",
    "native_decode",
    "native_serving",
    "native_quant",
    "native_tt",
    "http_serving",
]

# Env knobs that keep the --quick run short enough for CI.
QUICK_ENV = {
    "GREENFORMER_BENCH_REQUESTS": "64",
    "GREENFORMER_BENCH_DECODE_TOKENS": "32",
    "GREENFORMER_BENCH_DECODE_ITERS": "2",
    "GREENFORMER_BENCH_DECODE_SESSIONS": "4",
    "GREENFORMER_BENCH_SPEC_K": "3",
    "GREENFORMER_BENCH_TRAIN_STEPS": "8",
    "GREENFORMER_BENCH_QUANT": "quick",
    "GREENFORMER_BENCH_TT": "quick",
    "GREENFORMER_BENCH_HTTP_REQUESTS": "48",
}

# Headline fields worth surfacing per marker (everything is persisted; these
# just get echoed so a CI log shows the trajectory-relevant numbers).
HIGHLIGHTS = {
    "BENCH_NATIVE_DECODE": [
        "led_r25_speedup",
        "dense_batched_speedup",
        "led_r25_batched_speedup",
        "spec_speedup",
        "acceptance_rate",
    ],
    "BENCH_NATIVE_SERVING": ["led_r25_speedup"],
    "BENCH_HTTP": ["dense_rps", "led_r25_speedup"],
    "BENCH_KERNELS": [],
    "BENCH_NATIVE_TRAIN": [],
    "BENCH_QUANT": [
        "int8_speedup",
        "binary_speedup",
        "int8_agreement",
        "binary_agreement",
        "int8_compression",
    ],
    "BENCH_TT": [
        "tt_speedup",
        "tt_agreement",
        "tt_compression",
        "led_compression",
    ],
}

MARKER_RE = re.compile(r"^(BENCH_[A-Z0-9_]+) (\{.*\})\s*$")


def parse_bench_lines(stdout: str) -> list[tuple[str, dict]]:
    """Extract every ``BENCH_<MARKER> {json}`` pair from bench output.

    Any line that *starts* like a marker but fails to parse — truncated
    JSON, a non-object payload, a missing payload — raises ``ValueError``
    instead of being dropped: a malformed line means the bench's emitter
    and this collector disagree, and silently losing the datapoint would
    let the perf trajectory rot unnoticed.
    """
    found = []
    for raw in stdout.splitlines():
        line = raw.strip()
        if not line.startswith("BENCH_"):
            continue
        m = MARKER_RE.match(line)
        if not m:
            raise ValueError(f"malformed bench marker line (no JSON object payload): {line!r}")
        def _reject_constant(name: str):
            # NaN/Infinity are json-module extensions, not JSON — a bench
            # emitting them would break every strict consumer downstream.
            raise ValueError(f"non-JSON constant {name!r}")

        try:
            data = json.loads(m.group(2), parse_constant=_reject_constant)
        except (json.JSONDecodeError, ValueError) as e:
            raise ValueError(f"bad JSON after {m.group(1)}: {e} in {line!r}") from e
        if not isinstance(data, dict):
            raise ValueError(f"{m.group(1)} payload must be a JSON object, got: {line!r}")
        found.append((m.group(1), data))
    return found


def repo_root() -> str:
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(os.path.dirname(here))


def git_rev(root: str) -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=root,
            capture_output=True,
            text=True,
            check=True,
        )
        return out.stdout.strip()
    except Exception:  # noqa: BLE001 - best effort; benches still persist
        return "unknown"


def run_bench(root: str, name: str, quick: bool) -> list[tuple[str, dict]]:
    """Run one bench binary, return (marker, payload) pairs it printed."""
    env = dict(os.environ)
    if quick:
        for k, v in QUICK_ENV.items():
            env.setdefault(k, v)
    cmd = ["cargo", "bench", "--bench", name]
    print(f"[collect_bench] running: {' '.join(cmd)}", flush=True)
    proc = subprocess.run(cmd, cwd=root, env=env, capture_output=True, text=True)
    sys.stdout.write(proc.stdout)
    if proc.returncode != 0:
        sys.stderr.write(proc.stderr)
        raise RuntimeError(f"bench {name} failed with rc={proc.returncode}")
    try:
        return parse_bench_lines(proc.stdout)
    except ValueError as e:
        raise RuntimeError(f"bench {name}: {e}") from e


def persist(root: str, marker: str, bench: str, data: dict, rev: str) -> str:
    path = os.path.join(root, f"{marker}.json")
    doc = {"runs": []}
    if os.path.exists(path):
        try:
            with open(path, encoding="utf-8") as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError):
            pass
    runs = doc.setdefault("runs", [])
    runs.append(
        {
            "ts": datetime.datetime.now(datetime.timezone.utc).isoformat(timespec="seconds"),
            "git": rev,
            "bench": bench,
            "data": data,
        }
    )
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    return path


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--benches", nargs="+", default=DEFAULT_BENCHES)
    ap.add_argument("--quick", action="store_true", help="scale benches down via env knobs")
    args = ap.parse_args()

    root = repo_root()
    rev = git_rev(root)
    persisted = []
    failures = 0
    for bench in args.benches:
        try:
            markers = run_bench(root, bench, args.quick)
        except RuntimeError as e:
            print(f"[collect_bench] {e}", file=sys.stderr)
            failures += 1
            continue
        if not markers:
            print(f"[collect_bench] {bench}: no BENCH_* line found", file=sys.stderr)
        for marker, data in markers:
            persisted.append(persist(root, marker, bench, data, rev))
            shown = [
                f"{k}={data[k]}" for k in HIGHLIGHTS.get(marker, []) if k in data
            ]
            if shown:
                print(f"[collect_bench] {marker}: {' '.join(shown)}")
    for p in persisted:
        print(f"[collect_bench] wrote {p}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
