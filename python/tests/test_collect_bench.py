"""collect_bench marker parsing: malformed BENCH_* lines must fail loudly.

The collector used to drop unparseable marker lines with a stderr note and
report success — a bench could emit garbage forever and the trajectory
files would quietly stop accumulating. ``parse_bench_lines`` now raises on
any line that starts like a marker but does not carry a JSON object.
Hermetic (no cargo, no jax): exercises the pure parsing layer only.
"""

import pytest

from tools.collect_bench import DEFAULT_BENCHES, HIGHLIGHTS, QUICK_ENV, MARKER_RE, parse_bench_lines


def test_parses_markers_and_ignores_ordinary_output():
    out = "\n".join(
        [
            "== native quantized decode ==",
            "precision  tok/s",
            'BENCH_QUANT {"f32_tps":100.5,"int8_agreement":1.0}',
            "   BENCH_NATIVE_DECODE {\"dense_tps\":42} ",  # leading/trailing ws ok
            "benchmark BENCH_LOOKALIKE in prose is not a marker line",
        ]
    )
    got = parse_bench_lines(out)
    assert got == [
        ("BENCH_QUANT", {"f32_tps": 100.5, "int8_agreement": 1.0}),
        ("BENCH_NATIVE_DECODE", {"dense_tps": 42}),
    ]


def test_empty_and_markerless_output_yield_nothing():
    assert parse_bench_lines("") == []
    assert parse_bench_lines("all quiet\nno markers here\n") == []


@pytest.mark.parametrize(
    "line",
    [
        "BENCH_QUANT",  # no payload at all
        "BENCH_QUANT not-json",  # payload is not an object
        'BENCH_QUANT {"truncated":1',  # unbalanced JSON
        'BENCH_QUANT {"a":NaN}',  # NaN is not JSON
        "BENCH_QUANT [1, 2]",  # array, not object
    ],
)
def test_malformed_marker_lines_raise(line):
    with pytest.raises(ValueError):
        parse_bench_lines(f"ok line\n{line}\n")


def test_valid_json_non_object_payload_raises():
    # `{...}` regex gate passed but the payload parses to a non-dict: the
    # regex requires braces, so craft an object-looking string via nesting.
    with pytest.raises(ValueError):
        parse_bench_lines('BENCH_QUANT {"a"} \n')


def test_tt_bench_wired_into_default_set():
    # The TT panel bench rides the same collector: default set, quick env
    # knob, and highlight fields all present.
    assert "native_tt" in DEFAULT_BENCHES
    assert QUICK_ENV.get("GREENFORMER_BENCH_TT") == "quick"
    assert "tt_compression" in HIGHLIGHTS["BENCH_TT"]
    got = parse_bench_lines('BENCH_TT {"tt_compression":0.05,"tt_agreement":1.0}\n')
    assert got == [("BENCH_TT", {"tt_compression": 0.05, "tt_agreement": 1.0})]


def test_http_bench_wired_into_default_set():
    # The HTTP front-end bench rides the same collector: default set, quick
    # env knob, and highlight fields all present.
    assert "http_serving" in DEFAULT_BENCHES
    assert QUICK_ENV.get("GREENFORMER_BENCH_HTTP_REQUESTS") == "48"
    assert "led_r25_speedup" in HIGHLIGHTS["BENCH_HTTP"]
    got = parse_bench_lines('BENCH_HTTP {"dense_rps":120.0,"led_r25_speedup":1.4}\n')
    assert got == [("BENCH_HTTP", {"dense_rps": 120.0, "led_r25_speedup": 1.4})]


def test_marker_regex_shape_unchanged():
    # The Rust benches print `BENCH_<UPPER_SNAKE> {json}`; pin the contract.
    m = MARKER_RE.match('BENCH_QUANT {"x":1}')
    assert m and m.group(1) == "BENCH_QUANT" and m.group(2) == '{"x":1}'
    assert MARKER_RE.match("bench_quant {}") is None
