"""Kernel-vs-oracle correctness: the core L1 signal.

Hypothesis sweeps shapes (including awkward non-multiple-of-tile sizes) and
dtypes; every Pallas kernel must match its pure-jnp oracle in `kernels.ref`.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv, led, matmul, ref

ATOL = {jnp.float32: 2e-4}


def _rand(rng, *shape, dtype=np.float32):
    return jnp.asarray(rng.normal(size=shape).astype(dtype))


dims = st.integers(min_value=1, max_value=257)
small_dims = st.integers(min_value=1, max_value=48)
ranks = st.integers(min_value=1, max_value=32)


@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, n=dims, bias=st.booleans(), data=st.randoms())
def test_matmul_matches_ref(m, k, n, bias, data):
    rng = np.random.default_rng(data.randint(0, 2**31))
    x, w = _rand(rng, m, k), _rand(rng, k, n)
    b = _rand(rng, n) if bias else None
    got = matmul.matmul(x, w, b)
    want = ref.dense_matmul_ref(x, w, b)
    np.testing.assert_allclose(got, want, atol=ATOL[jnp.float32] * max(1, k // 16), rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, n=dims, r=ranks, bias=st.booleans(), data=st.randoms())
def test_led_matches_ref(m, k, n, r, bias, data):
    rng = np.random.default_rng(data.randint(0, 2**31))
    x, a, b = _rand(rng, m, k), _rand(rng, k, r), _rand(rng, r, n)
    bb = _rand(rng, n) if bias else None
    got = led.led_matmul(x, a, b, bb)
    want = ref.led_matmul_ref(x, a, b, bb)
    np.testing.assert_allclose(got, want, atol=2e-3 * max(1, k // 32), rtol=1e-4)


def test_matmul_batched_leading_dims():
    rng = np.random.default_rng(0)
    x = _rand(rng, 3, 5, 20)
    w = _rand(rng, 20, 7)
    got = matmul.matmul(x, w)
    want = ref.dense_matmul_ref(x, w)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)
    assert got.shape == (3, 5, 7)


def test_led_batched_leading_dims():
    rng = np.random.default_rng(1)
    x, a, b = _rand(rng, 2, 4, 16), _rand(rng, 16, 4), _rand(rng, 4, 9)
    got = led.led_matmul(x, a, b)
    np.testing.assert_allclose(got, ref.led_matmul_ref(x, a, b), atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("argnum", [0, 1, 2, 3])
def test_matmul_grads_match_ref(argnum):
    rng = np.random.default_rng(2)
    x, w, b = _rand(rng, 6, 30), _rand(rng, 30, 11), _rand(rng, 11)

    def f(x, w, b):
        return jnp.sum(matmul.matmul(x, w, b) ** 2)

    def fr(x, w, b):
        return jnp.sum(ref.dense_matmul_ref(x, w, b) ** 2)

    if argnum == 3:
        pytest.skip("matmul takes 3 args")
    g = jax.grad(f, argnums=argnum)(x, w, b)
    gr = jax.grad(fr, argnums=argnum)(x, w, b)
    np.testing.assert_allclose(g, gr, atol=5e-3, rtol=1e-3)


@pytest.mark.parametrize("argnum", [0, 1, 2, 3])
def test_led_grads_match_ref(argnum):
    rng = np.random.default_rng(3)
    x, a, b, bias = _rand(rng, 6, 30), _rand(rng, 30, 8), _rand(rng, 8, 11), _rand(rng, 11)

    def f(*args):
        return jnp.sum(led.led_matmul(*args) ** 2)

    def fr(*args):
        return jnp.sum(ref.led_matmul_ref(*args) ** 2)

    g = jax.grad(f, argnums=argnum)(x, a, b, bias)
    gr = jax.grad(fr, argnums=argnum)(x, a, b, bias)
    np.testing.assert_allclose(g, gr, atol=5e-3, rtol=1e-3)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(1, 3),
    hw=st.integers(5, 17),
    cin=st.integers(1, 5),
    cout=st.integers(1, 8),
    stride=st.sampled_from([1, 2]),
    padding=st.sampled_from(["SAME", "VALID"]),
    data=st.randoms(),
)
def test_conv2d_matches_lax(n, hw, cin, cout, stride, padding, data):
    rng = np.random.default_rng(data.randint(0, 2**31))
    kh = kw = 3
    if padding == "VALID" and hw < kh:
        return
    x = _rand(rng, n, hw, hw, cin)
    w = _rand(rng, kh, kw, cin, cout)
    b = _rand(rng, cout)
    got = conv.conv2d(x, w, b, stride, padding)
    want = ref.conv2d_ref(x, w, b, stride, padding)
    np.testing.assert_allclose(got, want, atol=1e-3, rtol=1e-3)


@settings(max_examples=10, deadline=None)
@given(
    hw=st.integers(6, 15),
    cin=st.integers(1, 4),
    cout=st.integers(2, 8),
    r=st.integers(1, 4),
    stride=st.sampled_from([1, 2]),
    data=st.randoms(),
)
def test_ced_conv2d_matches_lax(hw, cin, cout, r, stride, data):
    rng = np.random.default_rng(data.randint(0, 2**31))
    x = _rand(rng, 2, hw, hw, cin)
    a = _rand(rng, 3, 3, cin, r)
    b = _rand(rng, 1, 1, r, cout)
    bias = _rand(rng, cout)
    got = conv.ced_conv2d(x, a, b, bias, stride)
    want = ref.ced_conv2d_ref(x, a, b, bias, stride)
    np.testing.assert_allclose(got, want, atol=1e-3, rtol=1e-3)


def test_conv_grads_flow():
    """AD must flow through im2col into the Pallas matmul VJPs."""
    rng = np.random.default_rng(4)
    x = _rand(rng, 1, 8, 8, 2)
    w = _rand(rng, 3, 3, 2, 5)

    def f(w):
        return jnp.sum(conv.conv2d(x, w) ** 2)

    def fr(w):
        return jnp.sum(ref.conv2d_ref(x, w) ** 2)

    g, gr = jax.grad(f)(w), jax.grad(fr)(w)
    np.testing.assert_allclose(g, gr, atol=5e-3, rtol=1e-3)


def test_led_vmem_model_under_budget():
    """The fused LED kernel's per-program VMEM must fit the 16 MiB budget for
    every linear shape the model zoo emits (DESIGN.md §4)."""
    from compile import aot
    from compile.rank import rank_for

    shapes = []
    tc, lc = aot.TEXT_CFG, aot.LM_CFG
    for k, n in [(tc.d, tc.d), (tc.d, tc.ff), (tc.ff, tc.d), (lc.d, lc.ff), (lc.ff, lc.d), (lc.d, lc.vocab)]:
        for ratio in aot.RATIOS:
            r = rank_for(k, n, ratio)
            if r is not None:
                shapes.append((k, r, n))
    budget = 16 * 1024 * 1024
    for k, r, n in shapes:
        assert led.vmem_bytes(led.BLOCK_M, k, r, n) < budget, (k, r, n)


def test_matmul_kernel_blocks_divide_padded_shapes():
    """Padding in matmul_2d must never change the result."""
    rng = np.random.default_rng(5)
    # Shapes chosen to exercise every padding branch: below, equal, above tile.
    for m, k, n in [(1, 1, 1), (128, 128, 128), (129, 127, 130), (255, 3, 257)]:
        x, w = _rand(rng, m, k), _rand(rng, k, n)
        got = matmul.matmul_2d(x, w)
        want = jnp.matmul(x, w)
        np.testing.assert_allclose(got, want, atol=1e-3, rtol=1e-3)
