"""Layer-level checks: dense/LED dispatch, filter semantics, init fidelity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers
from compile.kernels import ref

KEY = jax.random.PRNGKey(11)


def test_init_linear_dense_when_no_ratio():
    p = layers.init_linear(KEY, 32, 48, None, "svd", 10)
    assert set(p) == {"w", "bias"}
    assert p["w"].shape == (32, 48)


def test_init_linear_factorizes_with_svd_faithfully():
    p = layers.init_linear(KEY, 128, 128, 0.5, "svd", 10)
    assert set(p) == {"a", "b", "bias"}
    assert p["a"].shape == (128, 32)  # rank_for(128,128,0.5) = 32
    # SVD init: A@B approximates the glorot W it was built from in
    # distribution — check the product's variance is glorot-like.
    prod_var = float(jnp.var(p["a"] @ p["b"]))
    glorot_var = (2.0 * (128 + 128)) ** -1 * 2  # 1/(fan_avg) * ...
    assert prod_var < 0.1  # sane scale, not exploded


def test_init_linear_gate_rejects_small():
    p = layers.init_linear(KEY, 8, 8, 0.9, "svd", 10)
    assert "w" in p  # r_max = 4 < MIN_RANK -> dense


def test_apply_linear_dispatch_matches_refs():
    x = jax.random.normal(KEY, (4, 32))
    dense = layers.init_linear(KEY, 32, 16, None, "svd", 5)
    got = layers.apply_linear(dense, x)
    np.testing.assert_allclose(
        got, ref.dense_matmul_ref(x, dense["w"], dense["bias"]), atol=1e-4, rtol=1e-4
    )
    fact = layers.init_linear(KEY, 128, 64, 0.25, "svd", 5)
    x2 = jax.random.normal(KEY, (4, 128))
    got = layers.apply_linear(fact, x2)
    np.testing.assert_allclose(
        got, ref.led_matmul_ref(x2, fact["a"], fact["b"], fact["bias"]), atol=2e-3, rtol=1e-3
    )


def test_init_conv_ced_shapes_follow_paper_rearrangement():
    p = layers.init_conv(KEY, 3, 3, 16, 32, 0.5, "svd", 5)
    # m = 144, n = 32, r_max = 26.18 -> rank 8
    assert p["a"].shape == (3, 3, 16, 8)
    assert p["b"].shape == (1, 1, 8, 32)


def test_maybe_ratio_filter():
    assert layers._maybe_ratio("block0/fc1", 0.5, None) == 0.5
    assert layers._maybe_ratio("block0/fc1", 0.5, ["fc1"]) == 0.5
    assert layers._maybe_ratio("block0/attn/q", 0.5, ["fc1"]) is None
    assert layers._maybe_ratio("anything", None, ["fc1"]) is None


def test_layernorm_matches_ref():
    x = jax.random.normal(KEY, (2, 5, 16))
    p = layers.init_layernorm(16)
    np.testing.assert_allclose(
        layers.apply_layernorm(p, x), ref.layernorm_ref(x, p["g"], p["bias"]), atol=1e-5
    )


def test_attention_shape_and_causality():
    d, h, s = 32, 4, 10
    p = layers.init_attention(KEY, d, "attn", None, "svd", 5, None)
    x = jax.random.normal(KEY, (2, s, d))
    out = layers.attention(p, x, h, causal=True)
    assert out.shape == (2, s, d)
    # Causality: output at position t must not change when future tokens do.
    x2 = x.at[:, -1, :].set(99.0)
    out2 = layers.attention(p, x2, h, causal=True)
    np.testing.assert_allclose(out[:, :-1], out2[:, :-1], atol=1e-4)
    # And WOULD change without the mask.
    out3 = layers.attention(p, x2, h, causal=False)
    assert float(jnp.max(jnp.abs(out3[:, 0] - layers.attention(p, x, h, False)[:, 0]))) > 1e-3


@pytest.mark.parametrize("solver", ["svd", "snmf", "random"])
def test_all_solvers_produce_runnable_layers(solver):
    p = layers.init_linear(KEY, 64, 64, 0.5, solver, 5)
    x = jax.random.normal(KEY, (3, 64))
    out = layers.apply_linear(p, x)
    assert out.shape == (3, 64)
    assert bool(jnp.all(jnp.isfinite(out)))
