"""Model-level checks: shapes, variant structure, learning, factorization
fidelity (post-training SVD at high rank ~ dense), filtering semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import layers, solvers
from compile.rank import rank_for

KEY = jax.random.PRNGKey(7)
SMALL_TEXT = M.TextConfig(vocab=64, seq=16, d=64, heads=2, layers=1, ff=128, classes=3)
SMALL_LM = M.LMConfig(vocab=64, seq=24, d=64, heads=2, layers=1, ff=128)
SMALL_IMG = M.ImageConfig(hw=12, ch=1, classes=3, c1=8, c2=16, fc=32)


def test_text_forward_shapes():
    for v in (M.Variant(), M.Variant(ratio=0.5), M.Variant(ratio=0.25, solver="random")):
        p = M.init_text(KEY, SMALL_TEXT, v)
        out = M.text_forward(p, SMALL_TEXT, jnp.zeros((5, 16), jnp.int32))
        assert out.shape == (5, 3)


def test_image_forward_shapes():
    for v in (M.Variant(), M.Variant(ratio=0.5)):
        p = M.init_image(KEY, SMALL_IMG, v)
        out = M.image_forward(p, SMALL_IMG, jnp.zeros((4, 12, 12, 1)))
        assert out.shape == (4, 3)


def test_lm_forward_shapes():
    p = M.init_lm(KEY, SMALL_LM, M.Variant(ratio=0.5))
    out = M.lm_forward(p, SMALL_LM, jnp.zeros((2, 24), jnp.int32))
    assert out.shape == (2, 24, 64)


def test_variant_changes_param_structure():
    dense = M.init_text(KEY, SMALL_TEXT, M.Variant())
    fact = M.init_text(KEY, SMALL_TEXT, M.Variant(ratio=0.5))
    dn = {n for n, _ in M.flatten_params(dense)}
    fn = {n for n, _ in M.flatten_params(fact)}
    assert "block0/attn/q/w" in dn and "block0/attn/q/w" not in fn
    assert "block0/attn/q/a" in fn and "block0/attn/q/b" in fn
    # head (64 x 3): r_max = 2.87 < MIN_RANK -> gate rejects, stays dense
    assert "head/w" in fn


def test_filter_restricts_factorization():
    v = M.Variant(ratio=0.5, filters=("fc1", "fc2"))
    p = M.init_text(KEY, SMALL_TEXT, v)
    names = {n for n, _ in M.flatten_params(p)}
    assert "block0/fc1/a" in names
    assert "block0/attn/q/w" in names  # attention untouched by filter


def test_factorized_has_fewer_params():
    cfg = M.TextConfig()
    dense = M.init_text(KEY, cfg, M.Variant())
    fact = M.init_text(KEY, cfg, M.Variant(ratio=0.25))
    n_dense = sum(int(np.prod(t.shape)) for _, t in M.flatten_params(dense))
    n_fact = sum(int(np.prod(t.shape)) for _, t in M.flatten_params(fact))
    assert n_fact < n_dense


def test_post_training_svd_preserves_logits_on_low_rank_weights():
    """Post-training factorization's promise holds when weights have low
    effective rank (as trained weights do — the paper's whole premise).
    Build a model whose linear weights are exactly rank-10 plus tiny noise;
    SVD truncation at rank >= 16 must then barely move the logits."""
    cfg = SMALL_TEXT
    dense = M.init_text(KEY, cfg, M.Variant())

    def lowrankify(node, key):
        if isinstance(node, dict):
            if "w" in node and node["w"].ndim == 2:
                k, n = node["w"].shape
                k1, k2 = jax.random.split(key)
                u = jax.random.normal(k1, (k, 10)) / np.sqrt(k)
                vt = jax.random.normal(k2, (10, n)) / np.sqrt(10)
                w = u @ vt + 1e-4 * jax.random.normal(key, (k, n))
                return {"w": w.astype(jnp.float32), "bias": node["bias"]}
            return {kk: lowrankify(vv, jax.random.fold_in(key, hash(kk) % 2**31)) for kk, vv in node.items()}
        return node

    dense = lowrankify(dense, KEY)
    x = jax.random.randint(KEY, (4, cfg.seq), 0, cfg.vocab)
    base = M.text_forward(dense, cfg, x)

    def fact_tree(node):
        if isinstance(node, dict):
            if "w" in node and node["w"].ndim == 2:
                k, n = node["w"].shape
                r = rank_for(k, n, 0.5)  # rank 16 >= true rank 10
                if r is not None:
                    a, b = solvers.svd_factorize(node["w"], r)
                    return {"a": a, "b": b, "bias": node["bias"]}
            return {kk: fact_tree(vv) for kk, vv in node.items()}
        return node

    fact = fact_tree(dense)
    out = M.text_forward(fact, cfg, x)
    scale = float(jnp.max(jnp.abs(base))) + 1e-6
    assert float(jnp.max(jnp.abs(out - base))) < 0.05 * scale + 0.05


@pytest.mark.parametrize("variant", [M.Variant(), M.Variant(ratio=0.5), M.Variant(ratio=0.5, solver="random")])
def test_text_training_reduces_loss(variant):
    cfg = SMALL_TEXT
    p = M.init_text(KEY, cfg, variant)
    loss_fn = lambda params, x, y: M.softmax_xent(M.text_forward(params, cfg, x), y)
    step = jax.jit(M.make_train_step(loss_fn))
    m, v = M.tree_zeros_like(p), M.tree_zeros_like(p)
    x = jax.random.randint(KEY, (8, cfg.seq), 0, cfg.vocab)
    y = jnp.arange(8) % cfg.classes
    first = None
    for i in range(1, 13):
        p, m, v, loss = step(p, m, v, jnp.float32(i), x, y)
        first = first or float(loss)
    assert float(loss) < first * 0.7


def test_lm_training_reduces_loss():
    cfg = SMALL_LM
    p = M.init_lm(KEY, cfg, M.Variant(ratio=0.5))
    step = jax.jit(M.make_train_step(lambda params, t: M.lm_loss(params, cfg, t)))
    m, v = M.tree_zeros_like(p), M.tree_zeros_like(p)
    toks = jax.random.randint(KEY, (4, cfg.seq), 0, cfg.vocab)
    first = None
    for i in range(1, 9):
        p, m, v, loss = step(p, m, v, jnp.float32(i), toks)
        first = first or float(loss)
    assert float(loss) < first


def test_flatten_unflatten_roundtrip():
    p = M.init_text(KEY, SMALL_TEXT, M.Variant(ratio=0.5))
    flat = M.flatten_params(p)
    back = M.unflatten_params(flat)
    flat2 = M.flatten_params(back)
    assert [n for n, _ in flat] == [n for n, _ in flat2]
    for (_, a), (_, b) in zip(flat, flat2):
        np.testing.assert_array_equal(a, b)


def test_flatten_order_is_sorted_depth_first():
    p = {"b": {"y": jnp.zeros(1), "x": jnp.zeros(1)}, "a": jnp.zeros(1)}
    names = [n for n, _ in M.flatten_params(p)]
    assert names == ["a", "b/x", "b/y"]
