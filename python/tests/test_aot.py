"""AOT exporter contracts: HLO text validity, manifest consistency, GTZ format."""

import json
import struct
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

ART = Path(__file__).resolve().parents[2] / "artifacts"


def test_to_hlo_text_produces_parseable_module():
    fn = lambda x: (jnp.sum(x * 2.0),)
    text = aot.to_hlo_text(jax.jit(fn).lower(jax.ShapeDtypeStruct((4,), jnp.float32)))
    assert "HloModule" in text
    assert "parameter(0)" in text.replace(" ", "").replace("parameter(0)", "parameter(0)") or "parameter" in text


def test_gtz_roundtrip(tmp_path):
    tensors = [
        ("a/w", np.arange(12, dtype=np.float32).reshape(3, 4)),
        ("a/bias", np.zeros((4,), np.float32)),
        ("toks", np.array([1, 2, 3], np.int32)),
        ("scalar", np.float32(3.5).reshape(())),
    ]
    p = tmp_path / "t.gtz"
    aot.write_gtz(p, tensors)
    # hand-roll a reader to pin the byte layout rust relies on
    buf = p.read_bytes()
    assert buf[:4] == b"GTZ1"
    (count,) = struct.unpack_from("<I", buf, 4)
    assert count == 4
    off = 8
    for name, arr in tensors:
        (nlen,) = struct.unpack_from("<H", buf, off)
        off += 2
        assert buf[off : off + nlen].decode() == name
        off += nlen
        dtype, ndim = struct.unpack_from("<BB", buf, off)
        off += 2
        assert dtype == (0 if arr.dtype == np.float32 else 1)
        assert ndim == arr.ndim
        dims = struct.unpack_from(f"<{ndim}Q", buf, off)
        off += 8 * ndim
        assert tuple(dims) == arr.shape
        raw = np.frombuffer(buf, dtype=arr.dtype, count=arr.size, offset=off).reshape(arr.shape)
        np.testing.assert_array_equal(raw, arr)
        off += arr.nbytes
    assert off == len(buf)


def test_collect_ranks_matches_param_shapes():
    cfg = M.TextConfig(vocab=64, seq=16, d=64, heads=2, layers=1, ff=128)
    p = M.init_text(jax.random.PRNGKey(0), cfg, M.Variant(ratio=0.5))
    ranks = aot.collect_ranks(p)
    assert ranks["block0/attn/q"] == 16  # rank_for(64, 64, 0.5) = 16
    assert "head" not in ranks  # gate rejected


@pytest.mark.skipif(not (ART / "manifest.json").exists(), reason="run `make artifacts` first")
def test_manifest_consistent_with_files():
    man = json.loads((ART / "manifest.json").read_text())
    assert man["format"] == 1
    assert len(man["graphs"]) >= 10
    for g in man["graphs"]:
        f = ART / g["file"]
        assert f.exists(), g["name"]
        assert g["params"], g["name"]
        for spec in g["params"]:
            assert spec["dtype"] in ("f32", "i32")
    for c in man["checkpoints"]:
        assert (ART / c["file"]).exists()


@pytest.mark.skipif(not (ART / "manifest.json").exists(), reason="run `make artifacts` first")
def test_manifest_param_order_is_flatten_order():
    """The manifest's param list must equal flatten_params order for a fresh
    init — this is the contract the Rust literal marshalling relies on."""
    man = json.loads((ART / "manifest.json").read_text())
    g = next(g for g in man["graphs"] if g["name"] == "text_dense_fwd_b8")
    cfg = M.TextConfig(**g["config"])
    p = M.init_text(jax.random.PRNGKey(42), cfg, M.Variant())
    names = [n for n, _ in M.flatten_params(p)]
    assert [s["name"] for s in g["params"]] == names
