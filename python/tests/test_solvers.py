"""Solver contracts: SVD optimality, SNMF constraints, Random statistics.

These same contracts are asserted by the Rust property tests over
`rust/src/linalg` — the two implementations are pinned to each other through
the shared bounds, not through bit-identical outputs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import solvers
from compile.rank import MIN_RANK, RANK_MULTIPLE, PINNED_VECTORS, r_max, rank_for


def _matrix(rng, m, n):
    return jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))


@settings(max_examples=15, deadline=None)
@given(m=st.integers(4, 64), n=st.integers(4, 64), data=st.randoms())
def test_svd_truncation_is_optimal(m, n, data):
    """||W - AB||_F^2 must equal the sum of squared discarded singular values
    (Eckart–Young)."""
    rng = np.random.default_rng(data.randint(0, 2**31))
    w = _matrix(rng, m, n)
    r = max(1, min(m, n) // 2)
    a, b = solvers.svd_factorize(w, r)
    err = float(jnp.sum((w - a @ b) ** 2))
    s = np.linalg.svd(np.asarray(w), compute_uv=False)
    want = float(np.sum(s[r:] ** 2))
    assert err == pytest.approx(want, rel=1e-3, abs=1e-3)


def test_svd_full_rank_reconstructs_exactly():
    rng = np.random.default_rng(0)
    w = _matrix(rng, 12, 9)
    a, b = solvers.svd_factorize(w, 9)
    np.testing.assert_allclose(a @ b, w, atol=1e-4)


def test_svd_factor_norms_balanced():
    """The sqrt(S) split should give ||A||_F == ||B||_F."""
    rng = np.random.default_rng(1)
    w = _matrix(rng, 24, 16)
    a, b = solvers.svd_factorize(w, 8)
    na, nb = float(jnp.linalg.norm(a)), float(jnp.linalg.norm(b))
    assert na == pytest.approx(nb, rel=1e-3)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(6, 32), n=st.integers(6, 32), data=st.randoms())
def test_snmf_b_nonnegative_and_converges(m, n, data):
    rng = np.random.default_rng(data.randint(0, 2**31))
    w = _matrix(rng, m, n)
    r = max(2, min(m, n) // 3)
    a5, b5 = solvers.snmf_factorize(w, r, num_iter=5)
    a50, b50 = solvers.snmf_factorize(w, r, num_iter=50)
    assert float(jnp.min(b5)) >= 0.0
    assert float(jnp.min(b50)) >= 0.0
    e5 = float(jnp.linalg.norm(w - a5 @ b5))
    e50 = float(jnp.linalg.norm(w - a50 @ b50))
    assert e50 <= e5 * 1.01  # more iterations never makes it meaningfully worse


def test_snmf_beats_nothing_but_not_svd():
    """SVD is the optimal rank-r approximation; SNMF must be >= its error but
    still a real approximation (way below ||W||)."""
    rng = np.random.default_rng(2)
    w = _matrix(rng, 30, 20)
    r = 10
    asvd, bsvd = solvers.svd_factorize(w, r)
    asn, bsn = solvers.snmf_factorize(w, r, num_iter=100)
    esvd = float(jnp.linalg.norm(w - asvd @ bsvd))
    esn = float(jnp.linalg.norm(w - asn @ bsn))
    assert esn >= esvd * 0.999
    assert esn < float(jnp.linalg.norm(w))


def test_random_solver_shapes_and_scale():
    rng_key = jax.random.PRNGKey(0)
    w = jnp.zeros((64, 48))
    a, b = solvers.random_factorize(w, 16, key=rng_key)
    assert a.shape == (64, 16) and b.shape == (16, 48)
    prod_var = float(jnp.var(a @ b))
    glorot_var = 2.0 / (64 + 48)
    assert 0.2 * glorot_var < prod_var < 5.0 * glorot_var


def test_unknown_solver_raises():
    with pytest.raises(ValueError):
        solvers.factorize(jnp.zeros((4, 4)), 2, solver="qr")


# --- rank policy -----------------------------------------------------------

def test_rank_pinned_vectors():
    """Shared vectors with rust/src/factorize/rank.rs — keep in sync."""
    for (m, n, ratio), want in PINNED_VECTORS:
        assert rank_for(m, n, ratio) == want, (m, n, ratio)


@settings(max_examples=50, deadline=None)
@given(m=st.integers(1, 4096), n=st.integers(1, 4096), ratio=st.floats(0.01, 0.99))
def test_rank_gate_always_reduces_cost(m, n, ratio):
    r = rank_for(m, n, ratio)
    if r is not None:
        assert r * (m + n) < m * n  # Eq. 1 gate
        assert r % RANK_MULTIPLE == 0 or r == MIN_RANK
        assert r >= MIN_RANK


def test_r_max_formula():
    assert r_max(128, 128) == pytest.approx(64.0)
    assert r_max(768, 3072) == pytest.approx(614.4)
