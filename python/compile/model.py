"""L2 model zoo: the three architectures the paper's evaluation needs.

  * `text`  — transformer encoder classifier (the 3 text-classification tasks)
  * `image` — small CNN classifier (the 2 image-classification tasks)
  * `lm`    — causal decoder-only LM (the in-context-learning use case)

Each model exists in a family of *variants*: dense (the paper's uncompressed
baseline) and LED/CED-factorized at a rank ratio, optionally restricted by
Greenformer's submodule filter. A variant fixes the param pytree structure,
so each (model, variant) pair lowers to its own HLO graph; the weights are
runtime inputs, which is what lets the Rust side swap dense checkpoints,
post-training-factorized weights, or by-design-trained factors into the same
graph family without re-lowering.

Also defines the fused `train_step` (fwd + bwd + Adam) exported for the
Rust training driver — Python never runs at training time either.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from . import layers


# ---------------------------------------------------------------------------
# Configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TextConfig:
    vocab: int = 512
    seq: int = 64
    d: int = 128
    heads: int = 4
    layers: int = 2
    ff: int = 512
    classes: int = 4


@dataclass(frozen=True)
class ImageConfig:
    hw: int = 28
    ch: int = 1
    classes: int = 4
    c1: int = 16
    c2: int = 32
    fc: int = 128


@dataclass(frozen=True)
class LMConfig:
    vocab: int = 512
    seq: int = 128
    d: int = 192
    heads: int = 6
    layers: int = 4
    ff: int = 768


@dataclass(frozen=True)
class Variant:
    """A factorization decision: Greenformer's auto_fact arguments."""

    ratio: float | None = None  # None => dense baseline
    solver: str = "svd"
    num_iter: int = 50
    filters: tuple[str, ...] | None = None  # submodule name filter

    @property
    def name(self) -> str:
        if self.ratio is None:
            return "dense"
        pct = int(round(self.ratio * 100))
        tag = f"led_r{pct:02d}"
        if self.filters:
            tag += "_f" + "-".join(self.filters)
        return tag


# ---------------------------------------------------------------------------
# Text classifier
# ---------------------------------------------------------------------------

def init_text(key, cfg: TextConfig, v: Variant) -> dict:
    keys = jax.random.split(key, cfg.layers + 3)
    f = list(v.filters) if v.filters is not None else None
    params = {
        "embed": layers.init_embedding(keys[0], cfg.vocab, cfg.d),
        "pos": {"table": jax.random.normal(keys[1], (cfg.seq, cfg.d), jnp.float32) * 0.02},
        "head": layers.init_linear(
            keys[2], cfg.d, cfg.classes,
            layers._maybe_ratio("head", v.ratio, f), v.solver, v.num_iter,
        ),
        "ln_f": layers.init_layernorm(cfg.d),
    }
    for i in range(cfg.layers):
        params[f"block{i}"] = layers.init_block(
            keys[3 + i], cfg.d, cfg.ff, f"block{i}", v.ratio, v.solver, v.num_iter, f
        )
    return params


def text_forward(params: dict, cfg: TextConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    """tokens: (B, S) int32 -> logits (B, classes). Mean-pool over tokens."""
    x = layers.apply_embedding(params["embed"], tokens) + params["pos"]["table"]
    for i in range(cfg.layers):
        x = layers.transformer_block(params[f"block{i}"], x, cfg.heads, causal=False)
    x = layers.apply_layernorm(params["ln_f"], x)
    pooled = jnp.mean(x, axis=1)
    return layers.apply_linear(params["head"], pooled)


# ---------------------------------------------------------------------------
# Image classifier (CNN -> CED factorization path)
# ---------------------------------------------------------------------------

def init_image(key, cfg: ImageConfig, v: Variant) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    f = list(v.filters) if v.filters is not None else None
    flat = (cfg.hw // 4) * (cfg.hw // 4) * cfg.c2
    return {
        "conv1": layers.init_conv(
            k1, 3, 3, cfg.ch, cfg.c1,
            layers._maybe_ratio("conv1", v.ratio, f), v.solver, v.num_iter,
        ),
        "conv2": layers.init_conv(
            k2, 3, 3, cfg.c1, cfg.c2,
            layers._maybe_ratio("conv2", v.ratio, f), v.solver, v.num_iter,
        ),
        "fc1": layers.init_linear(
            k3, flat, cfg.fc, layers._maybe_ratio("fc1", v.ratio, f), v.solver, v.num_iter
        ),
        "fc2": layers.init_linear(
            k4, cfg.fc, cfg.classes,
            layers._maybe_ratio("fc2", v.ratio, f), v.solver, v.num_iter,
        ),
    }


def _maxpool2(x: jnp.ndarray) -> jnp.ndarray:
    b, h, w, c = x.shape
    return jnp.max(x.reshape(b, h // 2, 2, w // 2, 2, c), axis=(2, 4))


def image_forward(params: dict, cfg: ImageConfig, images: jnp.ndarray) -> jnp.ndarray:
    """images: (B, H, W, C) f32 -> logits (B, classes)."""
    x = layers.apply_conv(params["conv1"], images)
    x = _maxpool2(jax.nn.relu(x))
    x = layers.apply_conv(params["conv2"], x)
    x = _maxpool2(jax.nn.relu(x))
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(layers.apply_linear(params["fc1"], x))
    return layers.apply_linear(params["fc2"], x)


# ---------------------------------------------------------------------------
# Causal LM (ICL use case)
# ---------------------------------------------------------------------------

def init_lm(key, cfg: LMConfig, v: Variant) -> dict:
    keys = jax.random.split(key, cfg.layers + 3)
    f = list(v.filters) if v.filters is not None else None
    params = {
        "embed": layers.init_embedding(keys[0], cfg.vocab, cfg.d),
        "pos": {"table": jax.random.normal(keys[1], (cfg.seq, cfg.d), jnp.float32) * 0.02},
        "head": layers.init_linear(
            keys[2], cfg.d, cfg.vocab,
            layers._maybe_ratio("head", v.ratio, f), v.solver, v.num_iter,
        ),
        "ln_f": layers.init_layernorm(cfg.d),
    }
    for i in range(cfg.layers):
        params[f"block{i}"] = layers.init_block(
            keys[3 + i], cfg.d, cfg.ff, f"block{i}", v.ratio, v.solver, v.num_iter, f
        )
    return params


def lm_forward(params: dict, cfg: LMConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    """tokens: (B, S) int32 -> next-token logits (B, S, vocab)."""
    s = tokens.shape[1]
    x = layers.apply_embedding(params["embed"], tokens) + params["pos"]["table"][:s]
    for i in range(cfg.layers):
        x = layers.transformer_block(params[f"block{i}"], x, cfg.heads, causal=True)
    x = layers.apply_layernorm(params["ln_f"], x)
    return layers.apply_linear(params["head"], x)


# ---------------------------------------------------------------------------
# Losses + fused Adam train step
# ---------------------------------------------------------------------------

def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean cross-entropy; labels are int class ids over the last logit dim."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def lm_loss(params, cfg: LMConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    """Next-token prediction over the full sequence."""
    logits = lm_forward(params, cfg, tokens[:, :-1])
    return softmax_xent(logits, tokens[:, 1:])


@dataclass(frozen=True)
class AdamConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8


def make_train_step(loss_fn, adam: AdamConfig = AdamConfig()):
    """Returns train_step(params, m, v, step, *batch) -> (params, m, v, loss).

    One fused graph: forward, backward (through the Pallas custom VJPs), and
    the Adam update. `step` is a float32 scalar (1-based) used for bias
    correction. Exported by aot.py; driven from Rust.
    """

    def train_step(params, m, v, step, *batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, *batch)

        def upd(p, g, mi, vi):
            mi = adam.b1 * mi + (1.0 - adam.b1) * g
            vi = adam.b2 * vi + (1.0 - adam.b2) * jnp.square(g)
            mhat = mi / (1.0 - adam.b1**step)
            vhat = vi / (1.0 - adam.b2**step)
            return p - adam.lr * mhat / (jnp.sqrt(vhat) + adam.eps), mi, vi

        stacked = jax.tree_util.tree_map(upd, params, grads, m, v)
        is_triple = lambda t: isinstance(t, tuple)
        new_p = jax.tree_util.tree_map(lambda t: t[0], stacked, is_leaf=is_triple)
        new_m = jax.tree_util.tree_map(lambda t: t[1], stacked, is_leaf=is_triple)
        new_v = jax.tree_util.tree_map(lambda t: t[2], stacked, is_leaf=is_triple)
        return new_p, new_m, new_v, loss

    return train_step


# ---------------------------------------------------------------------------
# Param flattening (the Rust interchange contract)
# ---------------------------------------------------------------------------

def flatten_params(params: dict, prefix: str = "") -> list[tuple[str, jnp.ndarray]]:
    """Deterministic depth-first, key-sorted flattening. The AOT manifest
    records the resulting name order; Rust marshals literals in exactly this
    order. Names look like `block0/attn/q/w`."""
    out = []
    for key in sorted(params.keys()):
        val = params[key]
        name = f"{prefix}{key}"
        if isinstance(val, dict):
            out.extend(flatten_params(val, name + "/"))
        else:
            out.append((name, val))
    return out


def unflatten_params(flat: list[tuple[str, jnp.ndarray]]) -> dict:
    """Inverse of flatten_params."""
    root: dict = {}
    for name, val in flat:
        parts = name.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return root


def tree_zeros_like(params: dict) -> dict:
    return jax.tree_util.tree_map(jnp.zeros_like, params)
