"""Rank policy shared between the JAX build path and the Rust toolkit.

This module is the single Python source of truth for Greenformer's rank
arithmetic (paper Eq. 1). `rust/src/factorize/rank.rs` mirrors it bit-for-bit;
`python/tests/test_rank.py` and the Rust property tests pin the same vectors
so the two implementations can never drift (the AOT graph shapes and the
checkpoint factor shapes must agree exactly).
"""

from __future__ import annotations

from dataclasses import dataclass

#: Factor ranks are rounded down to a multiple of this. 8 is the TPU lane
#: granularity (see DESIGN.md §4 Hardware adaptation); it also keeps the
#: MXU-utilization estimate honest for the skinny GEMMs LED produces.
RANK_MULTIPLE = 8

#: Smallest rank we will ever emit. Below this the factor matmuls are pure
#: overhead on every backend.
MIN_RANK = 8


def r_max(m: int, n: int) -> float:
    """Paper Eq. 1: the break-even rank of an (m, n) weight matrix.

    A rank-r factorization costs r*(m+n) parameters/FLOPs against m*n for
    the dense layer, so factorization only wins when r < m*n/(m+n).
    """
    return (m * n) / (m + n)


def rank_for(m: int, n: int, ratio: float) -> int | None:
    """Resolve a rank ratio to a concrete rank for an (m, n) weight.

    Returns None when the Eq.-1 gate rejects factorization (the resolved
    rank would not reduce theoretical cost), in which case the layer is
    left dense. Mirrored by `factorize::rank::rank_for` in Rust.
    """
    if m <= 0 or n <= 0 or ratio <= 0.0:
        return None
    rmax = r_max(m, n)
    r = int(ratio * rmax)
    r = (r // RANK_MULTIPLE) * RANK_MULTIPLE
    if r < MIN_RANK:
        r = MIN_RANK
    # Eq. 1 gate: only factorize when the rank strictly reduces cost.
    if float(r) >= rmax:
        return None
    return r


@dataclass(frozen=True)
class RankSpec:
    """A resolved factorization decision for one layer."""

    m: int
    n: int
    rank: int

    @property
    def dense_cost(self) -> int:
        return self.m * self.n

    @property
    def factored_cost(self) -> int:
        return self.rank * (self.m + self.n)

    @property
    def cost_ratio(self) -> float:
        return self.factored_cost / self.dense_cost


# Pinned vectors shared with rust/src/factorize/rank.rs::tests::pinned_vectors.
# (m, n, ratio) -> rank or None. Update both places together.
PINNED_VECTORS = [
    ((128, 128, 0.50), 32),
    ((128, 128, 0.25), 16),
    ((128, 128, 0.10), 8),
    ((128, 128, 0.90), 56),
    ((768, 768, 0.50), 192),
    ((768, 3072, 0.25), 152),
    ((768, 3072, 0.50), 304),
    ((512, 128, 0.75), 76 // RANK_MULTIPLE * RANK_MULTIPLE),  # 72
    ((16, 16, 0.50), None),  # r_max=8 -> MIN_RANK==r_max, gate rejects
    ((8, 8, 0.99), None),
    ((4096, 4096, 0.75), 1536),
]
