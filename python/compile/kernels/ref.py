"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness ground truth: `python/tests/test_kernels.py` sweeps
shapes/dtypes with hypothesis and asserts the Pallas kernels (interpret=True)
match these references to tight tolerances. Nothing in here is performance
sensitive — clarity over speed.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def dense_matmul_ref(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray | None = None) -> jnp.ndarray:
    """y = x @ w (+ b). x: (..., m, k), w: (k, n)."""
    y = jnp.matmul(x, w, preferred_element_type=jnp.float32)
    if b is not None:
        y = y + b
    return y.astype(x.dtype)


def led_matmul_ref(
    x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray, bias: jnp.ndarray | None = None
) -> jnp.ndarray:
    """LED layer oracle: y = (x @ A) @ B (+ bias).

    x: (..., m, k), a: (k, r), b: (r, n). This is the paper's Figure-3
    replacement for a dense (k, n) linear layer.
    """
    h = jnp.matmul(x, a, preferred_element_type=jnp.float32)
    y = jnp.matmul(h, b, preferred_element_type=jnp.float32)
    if bias is not None:
        y = y + bias
    return y.astype(x.dtype)


def conv2d_ref(
    x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray | None = None, stride: int = 1, padding: str = "SAME"
) -> jnp.ndarray:
    """Dense 2D convolution oracle. x: (N, H, W, Cin), w: (kh, kw, Cin, Cout)."""
    y = lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if b is not None:
        y = y + b
    return y


def ced_conv2d_ref(
    x: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    bias: jnp.ndarray | None = None,
    stride: int = 1,
    padding: str = "SAME",
) -> jnp.ndarray:
    """CED oracle: spatial conv to r channels (encoder) then 1x1 conv r->Cout.

    a: (kh, kw, Cin, r) — the paper's A in R^{Cin*S x r} reshaped back to a
    kernel; b: (1, 1, r, Cout) — the paper's B as a pointwise conv.
    """
    h = lax.conv_general_dilated(
        x,
        a,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    y = lax.conv_general_dilated(
        h,
        b,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if bias is not None:
        y = y + bias
    return y


def softmax_ref(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    m = jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def layernorm_ref(x: jnp.ndarray, g: jnp.ndarray, b: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b
