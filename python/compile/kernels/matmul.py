"""Tiled dense matmul Pallas kernel (the uncompressed baseline's hot spot).

The kernel follows the canonical TPU tiling: grid over (M/bm, N/bn, K/bk),
accumulating partial products into the output tile across the K grid axis.
On a real TPU each (bm, bk) x (bk, bn) tile contraction maps onto the MXU
systolic array; here we run with `interpret=True` (CPU PJRT cannot execute
Mosaic custom-calls — see DESIGN.md §4) so the same schedule lowers to plain
HLO and is validated numerically against `ref.dense_matmul_ref`.

Both the forward product and the custom VJP (dx = g @ w^T, dw = x^T @ g) are
expressed with the same kernel so the AOT-exported train_step graphs keep the
Pallas schedule on the backward pass too.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. Multiples of the 128-wide MXU tile; sized so the
# three per-program tiles (x, w, out) total 3 MiB — comfortably inside the
# 16 MiB VMEM budget while keeping the grid small (grid-step overhead
# dominates interpret-mode CPU execution; the EXPERIMENTS.md §Perf sweep
# measured 3.0x end-to-end from 128^3 -> 512^3). The wrapper shrinks tiles
# for small operands and pads to multiples so the grid always covers the
# operands exactly.
BLOCK_M = 512
BLOCK_N = 512
BLOCK_K = 512


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (bm, bn) output tile; K is the innermost grid axis (accumulate)."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _pad_to(x: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


def _block(size: int, target: int) -> int:
    """Largest tile <= target that is a multiple of 8 (or the full size)."""
    if size <= target:
        return size
    return target


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def matmul_2d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    block_m: int = BLOCK_M,
    block_n: int = BLOCK_N,
    block_k: int = BLOCK_K,
) -> jnp.ndarray:
    """y = x @ w for 2-D operands via the Pallas kernel. Pads to tile size."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch: {x.shape} @ {w.shape}"
    bm, bn, bk = _block(m, block_m), _block(n, block_n), _block(k, block_k)
    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w, 0, bk), 1, bn)
    mp, kp = xp.shape
    np_ = wp.shape[1]
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


def _flatten_leading(x: jnp.ndarray) -> tuple[jnp.ndarray, tuple[int, ...]]:
    lead = x.shape[:-1]
    return x.reshape((-1, x.shape[-1])), lead


@jax.custom_vjp
def matmul(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray | None = None) -> jnp.ndarray:
    """y = x @ w (+ b); x may carry leading batch dims. Pallas hot path."""
    x2, lead = _flatten_leading(x)
    y = matmul_2d(x2, w)
    if b is not None:
        y = y + b
    return y.reshape(lead + (w.shape[1],))


def _matmul_fwd(x, w, b):
    return matmul(x, w, b), (x, w, b is not None)


def _matmul_bwd(res, g):
    x, w, has_b = res
    g2, _ = _flatten_leading(g)
    x2, _ = _flatten_leading(x)
    dx = matmul_2d(g2, w.T).reshape(x.shape)
    dw = matmul_2d(x2.T, g2)
    db = jnp.sum(g2, axis=0) if has_b else None
    return dx, dw, db


matmul.defvjp(_matmul_fwd, _matmul_bwd)
