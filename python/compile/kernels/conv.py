"""Convolution layers routed through the Pallas matmul kernels via im2col.

Greenformer factorizes a conv weight W in R^{kh x kw x Cin x Cout} by
flattening it to W' in R^{(kh*kw*Cin) x Cout} (the paper's R^{Cin*S x Cout}
rearrangement), decomposing W' = A' B', and reshaping A' back into a conv
kernel with r output channels plus a 1x1 conv B (the CED layer, Figure 3).

With im2col the CED forward is *exactly* the fused LED kernel applied to the
patch matrix — so the conv path reuses `led.led_matmul` / `matmul.matmul`
unchanged, and autodiff flows through the (pure-jnp, differentiable) im2col
while the GEMMs keep their custom Pallas VJPs.

The im2col patch ordering is (kh, kw, Cin) row-major, matching the HWIO
weight flattening; `python/tests/test_kernels.py` pins this against
`ref.conv2d_ref` / `ref.ced_conv2d_ref` (lax.conv ground truth).
"""

from __future__ import annotations

import jax.numpy as jnp

from .led import led_matmul
from .matmul import matmul


def im2col(x: jnp.ndarray, kh: int, kw: int, stride: int = 1, padding: str = "SAME") -> tuple[jnp.ndarray, int, int]:
    """Extract conv patches. x: (N, H, W, C) -> (N, Ho, Wo, kh*kw*C).

    Patch channel order is (i, j, c) row-major — identical to flattening an
    HWIO kernel with `.reshape(kh*kw*C, Cout)`.
    """
    n, h, w, c = x.shape
    if padding == "SAME":
        ho = -(-h // stride)
        wo = -(-w // stride)
        pad_h = max((ho - 1) * stride + kh - h, 0)
        pad_w = max((wo - 1) * stride + kw - w, 0)
        x = jnp.pad(
            x,
            (
                (0, 0),
                (pad_h // 2, pad_h - pad_h // 2),
                (pad_w // 2, pad_w - pad_w // 2),
                (0, 0),
            ),
        )
    elif padding == "VALID":
        ho = (h - kh) // stride + 1
        wo = (w - kw) // stride + 1
    else:
        raise ValueError(f"unknown padding {padding!r}")

    cols = []
    for i in range(kh):
        for j in range(kw):
            patch = x[:, i : i + (ho - 1) * stride + 1 : stride, j : j + (wo - 1) * stride + 1 : stride, :]
            cols.append(patch)
    # (N, Ho, Wo, kh*kw, C) -> (N, Ho, Wo, kh*kw*C); stacking on axis 3 keeps
    # (i, j) major over C, matching the HWIO flatten.
    out = jnp.stack(cols, axis=3).reshape(n, ho, wo, kh * kw * c)
    return out, ho, wo


def conv2d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray | None = None,
    stride: int = 1,
    padding: str = "SAME",
) -> jnp.ndarray:
    """Dense conv2d via im2col + Pallas matmul. w: (kh, kw, Cin, Cout)."""
    kh, kw, cin, cout = w.shape
    patches, ho, wo = im2col(x, kh, kw, stride, padding)
    n = x.shape[0]
    y = matmul(patches.reshape(n * ho * wo, kh * kw * cin), w.reshape(kh * kw * cin, cout), b)
    return y.reshape(n, ho, wo, cout)


def ced_conv2d(
    x: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    bias: jnp.ndarray | None = None,
    stride: int = 1,
    padding: str = "SAME",
) -> jnp.ndarray:
    """CED conv2d: encoder a: (kh, kw, Cin, r), decoder b: (1, 1, r, Cout).

    Lowered as one fused LED matmul over the patch matrix — the factorized
    GEMM never materializes the rank-r feature map in HBM.
    """
    kh, kw, cin, r = a.shape
    _, _, r2, cout = b.shape
    assert r == r2, f"rank mismatch: {a.shape} vs {b.shape}"
    patches, ho, wo = im2col(x, kh, kw, stride, padding)
    n = x.shape[0]
    y = led_matmul(
        patches.reshape(n * ho * wo, kh * kw * cin),
        a.reshape(kh * kw * cin, r),
        b.reshape(r, cout),
        bias,
    )
    return y.reshape(n, ho, wo, cout)
