"""Fused LED (Linear Encoder-Decoder) Pallas kernel — the paper's hot spot.

A factorized linear layer computes y = (x @ A) @ B. Done naively this is two
GEMM dispatches with the (m, r) intermediate written to and re-read from HBM.
The whole point of Greenformer's efficiency claim is that r << min(k, n), so
the intermediate is tiny: this kernel fuses the two products, keeping the
(bm, r) intermediate tile in VMEM for the lifetime of the program — the
explicit-BlockSpec analogue of what the paper gets from fused cuBLAS calls
(DESIGN.md §4 Hardware adaptation).

Grid is (M/bm,): each program owns a row-block, loads A (k, r) and B (r, n)
whole (both are skinny by construction — the Eq.-1 gate guarantees
r < mn/(m+n) so A and B together are smaller than the dense W), computes
h = x_blk @ A then o_blk = h @ B. VMEM footprint per program:
bm*k + k*r + bm*r + r*n + bm*n floats; `flops::roofline` (Rust) and
`python/tests/test_vmem.py` check this stays under the 16 MiB VMEM budget
for every shape the models emit.

Custom VJP re-expresses the backward pass with the same fused kernel plus
`matmul_2d` for the factor gradients, so exported train graphs stay on the
Pallas schedule end-to-end.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _flatten_leading, _pad_to, matmul_2d

# Row-block: 512 keeps per-program VMEM < 5 MiB for every model-zoo shape
# (checked by flops::roofline tests) while quartering the grid-step count
# vs 128 (EXPERIMENTS.md §Perf).
BLOCK_M = 512


def _led_kernel(x_ref, a_ref, b_ref, o_ref):
    # h lives entirely in registers/VMEM: (bm, r). No HBM round-trip.
    h = jnp.dot(x_ref[...], a_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = jnp.dot(h, b_ref[...], preferred_element_type=jnp.float32).astype(
        o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("block_m",))
def led_matmul_2d(
    x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray, block_m: int = BLOCK_M
) -> jnp.ndarray:
    """y = (x @ a) @ b for 2-D x via the fused Pallas kernel."""
    m, k = x.shape
    k2, r = a.shape
    r2, n = b.shape
    assert k == k2 and r == r2, f"shape mismatch: {x.shape}, {a.shape}, {b.shape}"
    bm = min(m, block_m)
    xp = _pad_to(x, 0, bm)
    mp = xp.shape[0]
    out = pl.pallas_call(
        _led_kernel,
        grid=(mp // bm,),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, r), lambda i: (0, 0)),
            pl.BlockSpec((r, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, n), x.dtype),
        interpret=True,
    )(xp, a, b)
    return out[:m]


@jax.custom_vjp
def led_matmul(
    x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray, bias: jnp.ndarray | None = None
) -> jnp.ndarray:
    """y = (x @ a) @ b (+ bias); x may carry leading batch dims."""
    x2, lead = _flatten_leading(x)
    y = led_matmul_2d(x2, a, b)
    if bias is not None:
        y = y + bias
    return y.reshape(lead + (b.shape[1],))


def _led_fwd(x, a, b, bias):
    return led_matmul(x, a, b, bias), (x, a, b, bias is not None)


def _led_bwd(res, g):
    x, a, b, has_bias = res
    g2, _ = _flatten_leading(g)
    x2, _ = _flatten_leading(x)
    # Recompute h = x @ a (cheap: r columns) instead of saving it — the same
    # memory-over-compute trade the fused forward makes.
    h = matmul_2d(x2, a)
    db_mat = matmul_2d(h.T, g2)  # (r, n)
    dh = matmul_2d(g2, b.T)  # (m, r)
    da = matmul_2d(x2.T, dh)  # (k, r)
    # dx = dh @ a^T = (g b^T) a^T: fused again through the LED kernel.
    dx = led_matmul_2d(g2, b.T, a.T).reshape(x.shape)
    dbias = jnp.sum(g2, axis=0) if has_bias else None
    return dx, da, db_mat, dbias


led_matmul.defvjp(_led_fwd, _led_bwd)


def vmem_bytes(m_block: int, k: int, r: int, n: int, dtype_bytes: int = 4) -> int:
    """Per-program VMEM footprint of the fused kernel (see module docstring)."""
    floats = m_block * k + k * r + m_block * r + r * n + m_block * n
    return floats * dtype_bytes
