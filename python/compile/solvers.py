"""Factorization solvers: Random, SVD, SNMF — the paper's three options.

Build-path counterparts of `rust/src/linalg/{svd,snmf,random}.rs`. These run
only at artifact-build / experiment-setup time (factorization-by-design
initialization and test oracles); the Rust implementations own the
post-training path. `python/tests/test_solvers.py` pins both sides to the
same numerical contracts (reconstruction error bounds, sign conventions,
non-negativity).

All solvers return (A, B) with W ~= A @ B, A: (m, r), B: (r, n).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def svd_factorize(w: jnp.ndarray, r: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Truncated SVD: W = U S V^T; A = U_r sqrt(S_r), B = sqrt(S_r) V_r^T.

    The sqrt split balances the factor norms, which matters when the factors
    are subsequently *trained* (by-design use case): both receive gradients
    of comparable scale.
    """
    wn = np.asarray(w, dtype=np.float64)
    u, s, vt = np.linalg.svd(wn, full_matrices=False)
    sq = np.sqrt(s[:r])
    a = u[:, :r] * sq[None, :]
    b = sq[:, None] * vt[:r, :]
    return jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32)


def snmf_factorize(
    w: jnp.ndarray, r: int, num_iter: int = 50, seed: int = 0
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Semi-NMF (Ding, Li & Jordan 2010): W ~= A B with B >= 0, A free.

    Multiplicative updates on G = B^T (n, r) >= 0 with A solved in closed
    form each step: A = W G (G^T G)^-1. This matches the paper's description
    ("B is strictly nonnegative yet A has no restriction on signs").
    """
    wn = np.asarray(w, dtype=np.float64)
    m, n = wn.shape
    rng = np.random.default_rng(seed)
    g = np.abs(rng.normal(size=(n, r))) + 0.1  # B^T, kept nonnegative
    eps = 1e-9
    for _ in range(num_iter):
        gtg = g.T @ g
        a = wn @ g @ np.linalg.pinv(gtg)
        wta = wn.T @ a  # (n, r)
        ata = a.T @ a  # (r, r)
        pos = np.maximum(wta, 0.0)
        neg = np.maximum(-wta, 0.0)
        ata_pos = np.maximum(ata, 0.0)
        ata_neg = np.maximum(-ata, 0.0)
        num = pos + g @ ata_neg
        den = neg + g @ ata_pos + eps
        g = g * np.sqrt(num / den)
    gtg = g.T @ g
    a = wn @ g @ np.linalg.pinv(gtg)
    return jnp.asarray(a, jnp.float32), jnp.asarray(g.T, jnp.float32)


def random_factorize(
    w: jnp.ndarray, r: int, key: jax.Array | None = None
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Random solver: fresh factors sized from W and r, scaled so that the
    product A @ B has approximately W's glorot variance. Suitable only for
    factorization-by-design (it does not approximate W — paper §Design)."""
    m, n = w.shape
    if key is None:
        key = jax.random.PRNGKey(0)
    ka, kb = jax.random.split(key)
    # var(sum_r a*b) = r * va * vb; target vw ~ 2/(m+n) (glorot).
    vw = 2.0 / (m + n)
    va = vb = np.sqrt(vw / r)
    a = jax.random.normal(ka, (m, r), jnp.float32) * np.sqrt(va)
    b = jax.random.normal(kb, (r, n), jnp.float32) * np.sqrt(vb)
    return a, b


SOLVERS = ("random", "svd", "snmf")


def factorize(
    w: jnp.ndarray,
    r: int,
    solver: str = "svd",
    num_iter: int = 50,
    key: jax.Array | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Dispatch over the paper's three solvers (greenformer.auto_fact's
    `solver=` argument)."""
    if solver == "svd":
        return svd_factorize(w, r)
    if solver == "snmf":
        return snmf_factorize(w, r, num_iter=num_iter)
    if solver == "random":
        return random_factorize(w, r, key=key)
    raise ValueError(f"unknown solver {solver!r}; expected one of {SOLVERS}")
