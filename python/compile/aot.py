"""AOT exporter: lower every (model, variant, graph) to HLO text + manifest.

This is the single build-time entry point (`make artifacts`). For each model
in the zoo and each factorization variant the evaluation needs, it lowers

  * `fwd`   — inference graphs at the batch sizes the Rust coordinator serves
  * `train` — the fused fwd+bwd+Adam step driven by the Rust training loop

to **HLO text** (not serialized HloModuleProto: jax >= 0.5 emits 64-bit
instruction ids that xla_extension 0.5.1 rejects; the text parser reassigns
ids — see /opt/xla-example/README.md) and writes `artifacts/manifest.json`
describing every graph: parameter order (the flatten_params contract), input
and output specs, resolved per-layer ranks, and model config. It also dumps
the JAX-initialized parameters for each variant as a GTZ checkpoint so Rust
training starts from a pinned initialization.

Python runs exactly once, here. Nothing in `python/` is imported at runtime.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import struct
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

RATIOS = (0.10, 0.25, 0.50, 0.75)

TEXT_CFG = M.TextConfig()
IMAGE_CFG = M.ImageConfig()
LM_CFG = M.LMConfig()

# Batch sizes the Rust side drives. fwd_b1 is the latency benchmark graph;
# the larger fwd is the serving/throughput graph; train is the step graph.
TEXT_BATCHES = {"fwd": (1, 8, 32), "train": (32,)}
IMAGE_BATCHES = {"fwd": (1, 8, 32), "train": (32,)}
LM_BATCHES = {"fwd": (1, 4), "train": (8,)}


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# GTZ checkpoint format (mirrored by rust/src/tensor/gtz.rs)
# ---------------------------------------------------------------------------

DTYPE_CODES = {"float32": 0, "int32": 1}


def write_gtz(path: Path, tensors: list[tuple[str, np.ndarray]]) -> None:
    """GTZ1: magic, u32 count, then per tensor:
    u16 name_len | name utf8 | u8 dtype | u8 ndim | u64 dims... | raw LE data."""
    with open(path, "wb") as f:
        f.write(b"GTZ1")
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors:
            arr = np.asarray(arr)
            # ascontiguousarray promotes 0-d to 1-d; restore the true shape
            arr = np.ascontiguousarray(arr).reshape(arr.shape)
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", DTYPE_CODES[str(arr.dtype)], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<Q", d))
            f.write(arr.tobytes())


# ---------------------------------------------------------------------------
# Graph spec helpers
# ---------------------------------------------------------------------------

def _dtype_tag(x) -> str:
    return {"float32": "f32", "int32": "i32"}[str(x.dtype)]


def _spec(name: str, x) -> dict:
    return {"name": name, "shape": list(x.shape), "dtype": _dtype_tag(x)}


def collect_ranks(params: dict, prefix: str = "") -> dict[str, int]:
    """Resolved rank per factorized layer (for the manifest + cost model)."""
    out = {}
    for key in sorted(params.keys()):
        val = params[key]
        name = f"{prefix}{key}"
        if isinstance(val, dict):
            if "a" in val and "b" in val:
                out[name] = int(val["a"].shape[-1])
            else:
                out.update(collect_ranks(val, name + "/"))
    return out


MODELS = {
    "text": dict(cfg=TEXT_CFG, init=M.init_text, batches=TEXT_BATCHES),
    "image": dict(cfg=IMAGE_CFG, init=M.init_image, batches=IMAGE_BATCHES),
    "lm": dict(cfg=LM_CFG, init=M.init_lm, batches=LM_BATCHES),
}


def example_inputs(model: str, cfg, batch: int):
    if model == "text":
        return (jax.ShapeDtypeStruct((batch, cfg.seq), jnp.int32),)
    if model == "image":
        return (jax.ShapeDtypeStruct((batch, cfg.hw, cfg.hw, cfg.ch), jnp.float32),)
    if model == "lm":
        return (jax.ShapeDtypeStruct((batch, cfg.seq), jnp.int32),)
    raise ValueError(model)


def train_inputs(model: str, cfg, batch: int):
    if model == "text":
        return (
            jax.ShapeDtypeStruct((batch, cfg.seq), jnp.int32),
            jax.ShapeDtypeStruct((batch,), jnp.int32),
        )
    if model == "image":
        return (
            jax.ShapeDtypeStruct((batch, cfg.hw, cfg.hw, cfg.ch), jnp.float32),
            jax.ShapeDtypeStruct((batch,), jnp.int32),
        )
    if model == "lm":
        # full token sequence; the graph shifts internally
        return (jax.ShapeDtypeStruct((batch, cfg.seq), jnp.int32),)
    raise ValueError(model)


def forward_fn(model: str, cfg):
    if model == "text":
        return lambda params, x: M.text_forward(params, cfg, x)
    if model == "image":
        return lambda params, x: M.image_forward(params, cfg, x)
    if model == "lm":
        return lambda params, x: M.lm_forward(params, cfg, x)
    raise ValueError(model)


def loss_fn(model: str, cfg):
    if model == "text":
        return lambda params, x, y: M.softmax_xent(M.text_forward(params, cfg, x), y)
    if model == "image":
        return lambda params, x, y: M.softmax_xent(M.image_forward(params, cfg, x), y)
    if model == "lm":
        return lambda params, toks: M.lm_loss(params, cfg, toks)
    raise ValueError(model)


def cfg_dict(cfg) -> dict:
    return {k: getattr(cfg, k) for k in cfg.__dataclass_fields__}


# ---------------------------------------------------------------------------
# Export
# ---------------------------------------------------------------------------

def export_graph(path: Path, fn, example_args) -> str:
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    path.write_text(text)
    return hashlib.sha256(text.encode()).hexdigest()[:16]


def build(out_dir: Path, only: str | None = None, quick: bool = False) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    init_dir = out_dir / "init"
    init_dir.mkdir(exist_ok=True)
    manifest: dict = {"format": 1, "graphs": [], "checkpoints": []}
    key = jax.random.PRNGKey(42)

    variants = [M.Variant()] + [M.Variant(ratio=r) for r in RATIOS]
    if quick:
        variants = [M.Variant(), M.Variant(ratio=0.25)]

    for model_name, zoo in MODELS.items():
        if only and model_name != only:
            continue
        cfg = zoo["cfg"]
        for variant in variants:
            params = zoo["init"](key, cfg, variant)
            flat = M.flatten_params(params)
            ranks = collect_ranks(params)
            param_specs = [_spec(n, t) for n, t in flat]
            n_params = int(sum(int(np.prod(t.shape)) for _, t in flat))

            ckpt_name = f"{model_name}_{variant.name}.gtz"
            write_gtz(init_dir / ckpt_name, [(n, np.asarray(t)) for n, t in flat])
            manifest["checkpoints"].append(
                {
                    "model": model_name,
                    "variant": variant.name,
                    "file": f"init/{ckpt_name}",
                    "n_params": n_params,
                }
            )

            fwd = forward_fn(model_name, cfg)
            for batch in zoo["batches"]["fwd"]:
                gname = f"{model_name}_{variant.name}_fwd_b{batch}"
                fpath = out_dir / f"{gname}.hlo.txt"
                ex = example_inputs(model_name, cfg, batch)
                if not fpath.exists():
                    digest = export_graph(fpath, fwd, (params,) + ex)
                else:
                    digest = hashlib.sha256(fpath.read_bytes()).hexdigest()[:16]
                out_shape = jax.eval_shape(fwd, params, *ex)
                manifest["graphs"].append(
                    {
                        "name": gname,
                        "file": fpath.name,
                        "model": model_name,
                        "variant": variant.name,
                        "kind": "fwd",
                        "batch": batch,
                        "params": param_specs,
                        "inputs": [_spec("x", e) for e in ex],
                        "outputs": [_spec("out", out_shape)],
                        "ranks": ranks,
                        "n_params": n_params,
                        "config": cfg_dict(cfg),
                        "sha256_16": digest,
                    }
                )
                print(f"  {gname}: ok", flush=True)

            lf = loss_fn(model_name, cfg)
            step_fn = M.make_train_step(lf)
            for batch in zoo["batches"]["train"]:
                gname = f"{model_name}_{variant.name}_train_b{batch}"
                fpath = out_dir / f"{gname}.hlo.txt"
                ex = train_inputs(model_name, cfg, batch)
                zeros = M.tree_zeros_like(params)
                step_arg = jax.ShapeDtypeStruct((), jnp.float32)
                if not fpath.exists():
                    digest = export_graph(
                        fpath, step_fn, (params, zeros, zeros, step_arg) + ex
                    )
                else:
                    digest = hashlib.sha256(fpath.read_bytes()).hexdigest()[:16]
                # train graph inputs: params..., m..., v..., step, batch...;
                # outputs: params..., m..., v..., loss (same flat order both
                # sides — the Rust driver relies on this).
                manifest["graphs"].append(
                    {
                        "name": gname,
                        "file": fpath.name,
                        "model": model_name,
                        "variant": variant.name,
                        "kind": "train",
                        "batch": batch,
                        "params": param_specs,
                        "inputs": [_spec("x", e) for e in ex],
                        "outputs": [{"name": "loss", "shape": [], "dtype": "f32"}],
                        "ranks": ranks,
                        "n_params": n_params,
                        "config": cfg_dict(cfg),
                        "sha256_16": digest,
                    }
                )
                print(f"  {gname}: ok", flush=True)

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"wrote {len(manifest['graphs'])} graphs -> {out_dir}/manifest.json")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument("--only", default=None, help="export only this model (text|image|lm)")
    ap.add_argument("--quick", action="store_true", help="dense + r25 only (CI)")
    args = ap.parse_args()
    build(Path(args.out), only=args.only, quick=args.quick)


if __name__ == "__main__":
    main()
