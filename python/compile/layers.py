"""Functional NN layers over param dicts, dispatching dense vs factorized.

Every parameterized layer is a plain dict of arrays. A linear layer is either

  dense: {"w": (k, n), "bias": (n,)}
  LED:   {"a": (k, r), "b": (r, n), "bias": (n,)}      (paper Figure 3)

and a conv layer is either

  dense: {"w": (kh, kw, cin, cout), "bias": (cout,)}
  CED:   {"a": (kh, kw, cin, r), "b": (1, 1, r, cout), "bias": (cout,)}

`apply_linear` / `apply_conv` dispatch on the keys present, so the same model
forward function runs any mixture of factorized and dense layers — which is
exactly Greenformer's contract (LED/CED keep the layer's I/O signature).
The dict structure is static under tracing, so each variant lowers to its own
specialized HLO graph at AOT time.

All GEMMs route through the Pallas kernels in `kernels/`.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .kernels.conv import ced_conv2d, conv2d
from .kernels.led import led_matmul
from .kernels.matmul import matmul
from .rank import rank_for
from . import solvers


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def glorot(key, shape):
    fan_in, fan_out = shape[-2], shape[-1]
    if len(shape) == 4:  # conv HWIO
        rf = shape[0] * shape[1]
        fan_in, fan_out = rf * shape[2], rf * shape[3]
    limit = math.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, jnp.float32, -limit, limit)


def init_linear(key, k: int, n: int, ratio: float | None, solver: str, num_iter: int) -> dict:
    """Init a linear layer; factorize at init (factorization-by-design) when
    `ratio` is given and the Eq.-1 gate accepts."""
    kw, _ = jax.random.split(key)
    w = glorot(kw, (k, n))
    bias = jnp.zeros((n,), jnp.float32)
    r = rank_for(k, n, ratio) if ratio is not None else None
    if r is None:
        return {"w": w, "bias": bias}
    a, b = solvers.factorize(w, r, solver=solver, num_iter=num_iter, key=key)
    return {"a": a, "b": b, "bias": bias}


def init_conv(key, kh: int, kw_: int, cin: int, cout: int, ratio: float | None, solver: str, num_iter: int) -> dict:
    """Init a conv layer; CED-factorize via the paper's (Cin*S, Cout) rearrangement."""
    kk, _ = jax.random.split(key)
    w = glorot(kk, (kh, kw_, cin, cout))
    bias = jnp.zeros((cout,), jnp.float32)
    m = kh * kw_ * cin
    r = rank_for(m, cout, ratio) if ratio is not None else None
    if r is None:
        return {"w": w, "bias": bias}
    a2d, b2d = solvers.factorize(w.reshape(m, cout), r, solver=solver, num_iter=num_iter, key=key)
    return {
        "a": a2d.reshape(kh, kw_, cin, r),
        "b": b2d.reshape(1, 1, r, cout),
        "bias": bias,
    }


def init_layernorm(d: int) -> dict:
    return {"g": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def init_embedding(key, vocab: int, d: int) -> dict:
    return {"table": jax.random.normal(key, (vocab, d), jnp.float32) * 0.02}


# ---------------------------------------------------------------------------
# Application
# ---------------------------------------------------------------------------

def apply_linear(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    if "w" in p:
        return matmul(x, p["w"], p["bias"])
    return led_matmul(x, p["a"], p["b"], p["bias"])


def apply_conv(p: dict, x: jnp.ndarray, stride: int = 1, padding: str = "SAME") -> jnp.ndarray:
    if "w" in p:
        return conv2d(x, p["w"], p["bias"], stride, padding)
    return ced_conv2d(x, p["a"], p["b"], p["bias"], stride, padding)


def apply_layernorm(p: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * p["g"] + p["bias"]


def apply_embedding(p: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    return p["table"][tokens]


def attention(p: dict, x: jnp.ndarray, heads: int, causal: bool) -> jnp.ndarray:
    """Multi-head self-attention; all four projections go through
    apply_linear, so they factorize like any other linear layer."""
    b, s, d = x.shape
    dk = d // heads
    q = apply_linear(p["q"], x).reshape(b, s, heads, dk).transpose(0, 2, 1, 3)
    k = apply_linear(p["k"], x).reshape(b, s, heads, dk).transpose(0, 2, 1, 3)
    v = apply_linear(p["v"], x).reshape(b, s, heads, dk).transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(dk)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), jnp.bool_))
        scores = jnp.where(mask, scores, -1e9)
    w = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bhkd->bhqd", w, v).transpose(0, 2, 1, 3).reshape(b, s, d)
    return apply_linear(p["o"], ctx)


def transformer_block(p: dict, x: jnp.ndarray, heads: int, causal: bool) -> jnp.ndarray:
    """Pre-LN transformer block: x + attn(ln(x)); x + ffn(ln(x))."""
    x = x + attention(p["attn"], apply_layernorm(p["ln1"], x), heads, causal)
    h = apply_linear(p["fc1"], apply_layernorm(p["ln2"], x))
    h = jax.nn.gelu(h)
    return x + apply_linear(p["fc2"], h)


# ---------------------------------------------------------------------------
# Init helpers for composite modules
# ---------------------------------------------------------------------------

def _maybe_ratio(name: str, ratio: float | None, filters: list[str] | None) -> float | None:
    """Greenformer's submodule filter: factorize `name` only when it matches
    one of the filter substrings (or no filter is set)."""
    if ratio is None:
        return None
    if filters is None:
        return ratio
    return ratio if any(f in name for f in filters) else None


def init_attention(key, d: int, name: str, ratio, solver, num_iter, filters) -> dict:
    keys = jax.random.split(key, 4)
    return {
        proj: init_linear(
            keys[i], d, d, _maybe_ratio(f"{name}/{proj}", ratio, filters), solver, num_iter
        )
        for i, proj in enumerate(("q", "k", "v", "o"))
    }


def init_block(key, d: int, ff: int, name: str, ratio, solver, num_iter, filters) -> dict:
    ka, k1, k2 = jax.random.split(key, 3)
    return {
        "ln1": init_layernorm(d),
        "attn": init_attention(ka, d, f"{name}/attn", ratio, solver, num_iter, filters),
        "ln2": init_layernorm(d),
        "fc1": init_linear(k1, d, ff, _maybe_ratio(f"{name}/fc1", ratio, filters), solver, num_iter),
        "fc2": init_linear(k2, ff, d, _maybe_ratio(f"{name}/fc2", ratio, filters), solver, num_iter),
    }
