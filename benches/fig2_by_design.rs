//! E1 — Figure 2 (left): factorization-by-design.
//!
//! Regenerates the panel: per-variant relative performance + speedup,
//! averaged across the 5 tasks, plus a timing series of the by-design
//! training step (dense vs led_r25) so regressions in the train hot path
//! show up as bench deltas.
//!
//! Full panel: `GREENFORMER_STEPS=300 GREENFORMER_EVAL=256 cargo bench --bench fig2_by_design`

use greenformer::data::text::PolarityTask;
use greenformer::data::{batch, Split};
use greenformer::experiments::{by_design, ExpParams, FigEnv};
use greenformer::runtime::Engine;
use greenformer::train::Trainer;
use greenformer::util::Bench;

fn main() {
    let Ok(engine) = Engine::load_default() else {
        eprintln!("SKIP fig2_by_design bench: AOT artifacts / PJRT runtime unavailable");
        return;
    };
    let params = ExpParams::quick();

    // Regenerate and print the panel (the paper artifact).
    let result = by_design(&FigEnv::Pjrt(&engine), &params).expect("by-design harness");
    println!("\n{}", result.render());

    // Timing series: one fused train step, dense vs factorized.
    let ds = PolarityTask::new(64, 42);
    let mut bench = Bench::new("by_design_train_step");
    bench.max_iters = 20;
    for variant in ["dense", "led_r25"] {
        let mut trainer = Trainer::from_init(&engine, "text", variant).unwrap();
        let bsz = trainer.batch_size();
        let (x, y) = batch(&ds, Split::Train, 0, bsz, None);
        bench.bench(variant, || {
            trainer.train_step(&[x.clone(), y.clone()]).unwrap()
        });
    }
    if let Some(s) = bench.speedup("dense", "led_r25") {
        println!("train-step speedup led_r25 vs dense: {s:.2}x");
    }
}
