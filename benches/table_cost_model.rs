//! E5 — cost-model table: params/FLOPs/VMEM/speedup per (layer, ratio),
//! plus a predicted-vs-measured check: the analytical FLOPs speedup against
//! the wall-clock speedup of the corresponding AOT graphs.

use greenformer::data::text::PolarityTask;
use greenformer::data::{batch, Split};
use greenformer::experiments::tables::{cost_table, render_cost_table};
use greenformer::runtime::Engine;
use greenformer::tensor::ParamStore;
use greenformer::util::Bench;

fn main() {
    let rows = cost_table(&[0.10, 0.25, 0.50, 0.75]);
    println!("\n== E5: cost model ==\n{}", render_cost_table(&rows));

    // Predicted vs measured: text fwd at every variant.
    let Ok(engine) = Engine::load_default() else {
        eprintln!("SKIP table_cost_model measured half: AOT artifacts / PJRT runtime unavailable");
        return;
    };
    let ds = PolarityTask::new(64, 42);
    let mut bench = Bench::new("text_fwd_b32");
    bench.max_iters = 30;
    let mut dense_median = None;
    for variant in ["dense", "led_r10", "led_r25", "led_r50", "led_r75"] {
        let graph = engine.manifest().find("text", variant, "fwd", None).unwrap().clone();
        let params =
            ParamStore::load_gtz(engine.manifest().checkpoint("text", variant).unwrap()).unwrap();
        let (x, _) = batch(&ds, Split::Eval, 0, graph.batch, None);
        let stats = bench.bench(variant, || {
            engine.run_fwd(&graph, &params, &[x.clone()]).unwrap()
        });
        if let Some(stats) = stats {
            match variant {
                "dense" => dense_median = Some(stats.median_s),
                _ => {
                    if let Some(d) = dense_median {
                        println!("    -> measured speedup vs dense: {:.2}x", d / stats.median_s);
                    }
                }
            }
        }
    }
}
