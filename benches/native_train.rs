//! Artifact-free training bench: dense vs LED through the native
//! fwd+bwd+Adam interpreter.
//!
//! Measures steps/sec on the default text classifier for the dense model
//! and its Ratio(0.5) LED factorization — the training-side realization of
//! Figure 2's speedup axis (a factorized layer's backward is four skinny
//! GEMMs through the rank bottleneck instead of two wide ones). Runs
//! hermetically (no artifacts, no PJRT) and prints a machine-readable
//! `BENCH_NATIVE_TRAIN {...}` JSON line.
//!
//! Env: GREENFORMER_BENCH_TRAIN_STEPS (default 24) scales the measurement.

use std::time::Instant;

use greenformer::backend::native::{demo_variants, TextModelCfg};
use greenformer::backend::NativeBackend;
use greenformer::data::text::PolarityTask;
use greenformer::tensor::ParamStore;
use greenformer::train::Trainer;

const BACKEND: NativeBackend = NativeBackend;
const BATCH: usize = 8;
const WARMUP: usize = 2;

fn bench_variant(name: &str, params: ParamStore, ds: &PolarityTask, steps: usize) -> f64 {
    let mut trainer = Trainer::native(&BACKEND, "text", name, BATCH, params).expect("trainer");
    trainer.train_classifier(ds, WARMUP, None, |_| {}).expect("warmup");
    let t0 = Instant::now();
    trainer.train_classifier(ds, steps, None, |_| {}).expect("train");
    let sps = steps as f64 / t0.elapsed().as_secs_f64();
    let last = trainer.recent_loss(4);
    println!("{name:<10} {sps:>8.2} steps/s   (loss after {} steps: {last:.4})", trainer.step);
    sps
}

fn main() {
    let steps: usize = std::env::var("GREENFORMER_BENCH_TRAIN_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(24);
    let cfg = TextModelCfg::default();
    // Random-solver factorization: construction speed only — training cost
    // depends on factor shapes, not values.
    let (dense, led50) = demo_variants(&cfg, 42, 0.5).expect("variants");
    let ds = PolarityTask::new(cfg.seq, 7);

    println!(
        "== native training: dense vs LED (batch={BATCH}, steps={steps}, d={} ff={} seq={}) ==",
        cfg.d, cfg.ff, cfg.seq
    );
    let dense_sps = bench_variant("dense", dense, &ds, steps);
    let led_sps = bench_variant("led_r50", led50, &ds, steps);
    println!("train speedup led_r50 vs dense: {:.2}x", led_sps / dense_sps);
    println!(
        "BENCH_NATIVE_TRAIN {{\"steps\":{steps},\"batch\":{BATCH},\
         \"dense_steps_per_sec\":{dense_sps:.3},\"led_r50_steps_per_sec\":{led_sps:.3},\
         \"led_r50_speedup\":{:.3}}}",
        led_sps / dense_sps
    );
}
