//! Artifact-free serving bench: dense vs LED variants through the full
//! queue → router → batcher → native-backend path.
//!
//! Measures end-to-end request throughput (req/s) and p50/p95 client latency
//! at equal batch size for dense, Ratio(0.5) and Ratio(0.25) LED variants of
//! the default text classifier — the serving-level realization of Figure 2's
//! speedup axis. Runs hermetically (no artifacts, no PJRT) and prints a
//! machine-readable `BENCH_NATIVE_SERVING {...}` JSON line.
//!
//! Env: GREENFORMER_BENCH_REQUESTS (default 192) scales the measurement.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use greenformer::backend::native::{demo_variants, TextModelCfg};
use greenformer::coordinator::{
    serve_classifier_native, BatcherConfig, RoutePolicy, Router, ServeConfig, Tier,
};
use greenformer::data::text::PolarityTask;
use greenformer::data::{Dataset, Split};
use greenformer::tensor::ParamStore;

const MAX_BATCH: usize = 8;
const CLIENTS: usize = 8;

struct VariantStats {
    name: String,
    rps: f64,
    p50_us: u64,
    p95_us: u64,
}

fn bench_variant(name: &str, store: ParamStore, requests: usize) -> VariantStats {
    let mut variants = HashMap::new();
    variants.insert(name.to_string(), store);
    let router = Router::new(RoutePolicy::Static(name.to_string()), vec![name.to_string()])
        .expect("router");
    let handle = serve_classifier_native(
        "text",
        variants,
        router,
        ServeConfig::with_batcher(
            BatcherConfig {
                max_batch: MAX_BATCH,
                max_wait: Duration::from_millis(2),
            },
            4096,
        ),
    )
    .expect("serve_classifier_native");

    let ds = PolarityTask::new(64, 7);
    let per = requests.div_ceil(CLIENTS);
    let total = per * CLIENTS;
    let examples: Vec<Vec<i32>> = (0..total).map(|i| ds.example(Split::Eval, i).tokens).collect();

    // Warm caches/threads outside the timed region (histogram noise from
    // these 8 requests is negligible against `total`).
    for toks in examples.iter().take(MAX_BATCH) {
        handle.classify(toks.clone(), Tier::Quality).expect("warmup");
    }

    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..CLIENTS {
            let h = handle.clone();
            let exs = &examples;
            scope.spawn(move || {
                for i in 0..per {
                    h.classify(exs[c * per + i].clone(), Tier::Quality)
                        .expect("serving failed");
                }
            });
        }
    });
    let elapsed = t0.elapsed().as_secs_f64();
    VariantStats {
        name: name.to_string(),
        rps: total as f64 / elapsed,
        p50_us: handle.metrics.latency_percentile_us(50.0),
        p95_us: handle.metrics.latency_percentile_us(95.0),
    }
}

fn main() {
    let requests: usize = std::env::var("GREENFORMER_BENCH_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(192);
    let cfg = TextModelCfg::default();
    // Same seed → identical dense checkpoint across both ratio calls.
    let (dense, led50) = demo_variants(&cfg, 42, 0.5).expect("variants");
    let (_, led25) = demo_variants(&cfg, 42, 0.25).expect("variants");

    println!(
        "== native serving: dense vs LED (batch={MAX_BATCH}, clients={CLIENTS}, \
         requests={requests}, d={} ff={} seq={}) ==",
        cfg.d, cfg.ff, cfg.seq
    );
    println!("{:<10} {:>10} {:>10} {:>10}", "variant", "req/s", "p50(us)", "p95(us)");

    let cases = [("dense", dense), ("led_r50", led50), ("led_r25", led25)];
    let mut stats = Vec::new();
    for (name, store) in cases {
        let s = bench_variant(name, store, requests);
        println!("{:<10} {:>10.1} {:>10} {:>10}", s.name, s.rps, s.p50_us, s.p95_us);
        stats.push(s);
    }

    let get = |n: &str| stats.iter().find(|s| s.name == n).expect("stat");
    let (d, r50, r25) = (get("dense"), get("led_r50"), get("led_r25"));
    println!(
        "speedup vs dense: led_r50 {:.2}x  led_r25 {:.2}x",
        r50.rps / d.rps,
        r25.rps / d.rps
    );
    println!(
        "BENCH_NATIVE_SERVING {{\"requests\":{requests},\"max_batch\":{MAX_BATCH},\
         \"dense_rps\":{:.2},\"led_r50_rps\":{:.2},\"led_r25_rps\":{:.2},\
         \"dense_p50_us\":{},\"dense_p95_us\":{},\"led_r50_p50_us\":{},\"led_r50_p95_us\":{},\
         \"led_r25_p50_us\":{},\"led_r25_p95_us\":{},\"led_r25_speedup\":{:.3}}}",
        d.rps,
        r50.rps,
        r25.rps,
        d.p50_us,
        d.p95_us,
        r50.p50_us,
        r50.p95_us,
        r25.p50_us,
        r25.p95_us,
        r25.rps / d.rps
    );
}
