//! Artifact-free quantized-decode bench: the DESIGN.md §12 precision axis
//! (f32 / int8 / binary) on the native KV-cached decode path.
//!
//! Runs the [`greenformer::experiments::quant_panel`] harness: one LED
//! checkpoint (SVD at Ratio(0.5)), then per precision the greedy decode
//! throughput, agreement of the greedy token streams with f32 over seeded
//! prompts, quantized weight bytes, and the propagated worst-case
//! |Δlogit| bound from the quantization report. Decode is memory-bound, so
//! the bytes column is the mechanism behind the tok/s column.
//!
//! Prints the panel's aligned table plus a machine-readable
//! `BENCH_QUANT {...}` JSON line for `python/tools/collect_bench.py`.
//!
//! Env: GREENFORMER_BENCH_QUANT=quick switches to the small CI preset
//! (same preset as the library's panel smoke test).

use greenformer::experiments::{quant_panel, QuantPanelCfg};
use greenformer::factorize::WeightPrecision;

fn main() {
    let quick = std::env::var("GREENFORMER_BENCH_QUANT")
        .map(|v| v == "quick")
        .unwrap_or(false);
    let cfg = if quick { QuantPanelCfg::quick() } else { QuantPanelCfg::default() };
    println!(
        "== native quantized decode (d={} ff={} layers={} vocab={}, ratio={}, {} mode) ==",
        cfg.lm.d,
        cfg.lm.ff,
        cfg.lm.layers,
        cfg.lm.vocab,
        cfg.ratio,
        if quick { "quick" } else { "full" }
    );
    let panel = quant_panel(&cfg).expect("quant_panel");
    print!("{}", panel.render());

    let row = |p: WeightPrecision| {
        panel.points.iter().find(|pt| pt.precision == p).expect("panel row")
    };
    let (f, i8r, bin) = (
        row(WeightPrecision::F32),
        row(WeightPrecision::Int8),
        row(WeightPrecision::Binary),
    );
    // Bounds render as JSON numbers (`1.2e-3`) or `null`, never NaN — the
    // collector hard-fails on unparseable BENCH_ lines.
    let bound_json =
        |b: Option<f64>| b.map(|v| format!("{v:.6e}")).unwrap_or_else(|| "null".into());
    println!(
        "BENCH_QUANT {{\"prompts\":{},\"new_tokens\":{},\"quick\":{quick},\
         \"f32_tps\":{:.2},\"int8_tps\":{:.2},\"binary_tps\":{:.2},\
         \"int8_speedup\":{:.3},\"binary_speedup\":{:.3},\
         \"int8_agreement\":{:.3},\"binary_agreement\":{:.3},\
         \"f32_bytes\":{},\"int8_bytes\":{},\"binary_bytes\":{},\
         \"int8_compression\":{:.4},\"binary_compression\":{:.4},\
         \"int8_logit_bound\":{},\"binary_logit_bound\":{}}}",
        panel.prompts,
        panel.new_tokens,
        f.tokens_per_sec,
        i8r.tokens_per_sec,
        bin.tokens_per_sec,
        i8r.speedup,
        bin.speedup,
        i8r.agreement,
        bin.agreement,
        f.bytes,
        i8r.bytes,
        bin.bytes,
        i8r.compression,
        bin.compression,
        bound_json(i8r.logit_bound),
        bound_json(bin.logit_bound),
    );
}
