//! L3 hot-path microbenches: the linalg substrate (GEMM/GEMV old vs new,
//! transposed products, SVD variants, QR) — the profile targets of the
//! DESIGN.md §11 kernel layer.

use greenformer::linalg::{
    jacobi_svd, matmul_into, matmul_into_reference, randomized_svd, svd_factorize, thin_qr, Matrix,
};
use greenformer::util::{Bench, Pcg64};

fn main() {
    let mut rng = Pcg64::seeded(2);

    let mut bench = Bench::new("matmul");
    bench.max_iters = 30;
    for n in [128usize, 256, 512] {
        let a = Matrix::randn(n, n, 1.0, &mut rng);
        let b = Matrix::randn(n, n, 1.0, &mut rng);
        bench.bench(&format!("{n}x{n}"), || a.matmul(&b));
        bench.bench(&format!("{n}x{n}_legacy_serial"), || {
            let mut out = vec![0.0f32; n * n];
            matmul_into_reference(n, n, n, &a.data, &b.data, &mut out);
            out
        });
        if let Some(s) = bench.speedup(&format!("{n}x{n}_legacy_serial"), &format!("{n}x{n}")) {
            println!("    -> kernel speedup {n}x{n}: {s:.2}x");
        }
    }

    // The m=1 decode shape: column-split GEMV vs the serial baseline.
    let mut bench = Bench::new("gemv");
    bench.max_iters = 50;
    for (k, n) in [(192usize, 768usize), (768, 3072)] {
        let a = Matrix::randn(1, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let mut out = vec![0.0f32; n];
        bench.bench(&format!("new_1x{k}x{n}"), || {
            out.iter_mut().for_each(|v| *v = 0.0);
            matmul_into(1, k, n, &a.data, &b.data, &mut out);
            std::hint::black_box(out[0])
        });
        bench.bench(&format!("old_1x{k}x{n}"), || {
            out.iter_mut().for_each(|v| *v = 0.0);
            matmul_into_reference(1, k, n, &a.data, &b.data, &mut out);
            std::hint::black_box(out[0])
        });
        if let Some(s) = bench.speedup(&format!("old_1x{k}x{n}"), &format!("new_1x{k}x{n}")) {
            println!("    -> gemv speedup 1x{k}x{n}: {s:.2}x");
        }
    }

    // Transposed products, now routed through the packed parallel kernels.
    let mut bench = Bench::new("matmul_tn_nt");
    bench.max_iters = 30;
    let a = Matrix::randn(512, 256, 1.0, &mut rng);
    let b = Matrix::randn(512, 384, 1.0, &mut rng);
    bench.bench("tn_256x512x384", || a.matmul_tn(&b));
    let c = Matrix::randn(384, 256, 1.0, &mut rng);
    bench.bench("nt_512x256x384", || a.matmul_nt(&c));

    let mut bench = Bench::new("svd");
    bench.max_iters = 10;
    let w = Matrix::randn(128, 512, 1.0, &mut rng);
    bench.bench("jacobi_128x512", || jacobi_svd(&w));
    bench.bench("rsvd_128x512_r32", || randomized_svd(&w, 32, 10, 2));
    bench.bench("svd_factorize_128x512_r32", || svd_factorize(&w, 32));
    let big = Matrix::randn(768, 3072, 0.1, &mut rng);
    bench.bench("svd_factorize_768x3072_r152", || svd_factorize(&big, 152));

    let mut bench = Bench::new("qr");
    bench.max_iters = 20;
    let t = Matrix::randn(512, 64, 1.0, &mut rng);
    bench.bench("thin_qr_512x64", || thin_qr(&t));
}
