//! L3 hot-path microbenches: the linalg substrate (GEMM, SVD variants, QR)
//! — the profile targets of the §Perf pass.

use greenformer::linalg::{jacobi_svd, randomized_svd, svd_factorize, thin_qr, Matrix};
use greenformer::util::{Bench, Pcg64};

fn main() {
    let mut rng = Pcg64::seeded(2);

    let mut bench = Bench::new("matmul");
    bench.max_iters = 30;
    for n in [128usize, 256, 512] {
        let a = Matrix::randn(n, n, 1.0, &mut rng);
        let b = Matrix::randn(n, n, 1.0, &mut rng);
        bench.bench(&format!("{n}x{n}"), || a.matmul(&b));
    }

    let mut bench = Bench::new("svd");
    bench.max_iters = 10;
    let w = Matrix::randn(128, 512, 1.0, &mut rng);
    bench.bench("jacobi_128x512", || jacobi_svd(&w));
    bench.bench("rsvd_128x512_r32", || randomized_svd(&w, 32, 10, 2));
    bench.bench("svd_factorize_128x512_r32", || svd_factorize(&w, 32));
    let big = Matrix::randn(768, 3072, 0.1, &mut rng);
    bench.bench("svd_factorize_768x3072_r152", || svd_factorize(&big, 152));

    let mut bench = Bench::new("qr");
    bench.max_iters = 20;
    let t = Matrix::randn(512, 64, 1.0, &mut rng);
    bench.bench("thin_qr_512x64", || thin_qr(&t));
}
