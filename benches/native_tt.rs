//! Artifact-free TT-decode bench: the DESIGN.md §13 solver-family axis
//! (dense / LED / TT) on the native KV-cached decode path.
//!
//! Runs the [`greenformer::experiments::tt_panel`] harness: one LM whose
//! linear weights are Kronecker-structured (exactly TT-rank-1 at two
//! modes, full-rank to the flat SVD — the regime where the TT family wins
//! and LED's Eq.-1 gate cannot), factorized once with the LED solver and
//! once with the TT solver, then per variant the greedy decode throughput,
//! agreement of the greedy token streams with dense over seeded prompts,
//! and serialized weight bytes.
//!
//! Prints the panel's aligned table plus a machine-readable
//! `BENCH_TT {...}` JSON line for `python/tools/collect_bench.py`.
//!
//! Env: GREENFORMER_BENCH_TT=quick switches to the small CI preset
//! (same preset as the library's panel smoke test).

use greenformer::experiments::{tt_panel, TtPanelCfg};

fn main() {
    let quick = std::env::var("GREENFORMER_BENCH_TT")
        .map(|v| v == "quick")
        .unwrap_or(false);
    let cfg = if quick { TtPanelCfg::quick() } else { TtPanelCfg::default() };
    println!(
        "== native TT decode (d={} ff={} layers={} vocab={}, energy={}, {} mode) ==",
        cfg.lm.d,
        cfg.lm.ff,
        cfg.lm.layers,
        cfg.lm.vocab,
        cfg.energy,
        if quick { "quick" } else { "full" }
    );
    let panel = tt_panel(&cfg).expect("tt_panel");
    print!("{}", panel.render());

    let row = |v: &str| {
        panel
            .points
            .iter()
            .find(|pt| pt.variant.starts_with(v))
            .expect("panel row")
    };
    let (dense, led, tt) = (row("dense"), row("led"), row("tt"));
    println!(
        "BENCH_TT {{\"prompts\":{},\"new_tokens\":{},\"quick\":{quick},\
         \"dense_tps\":{:.2},\"led_tps\":{:.2},\"tt_tps\":{:.2},\
         \"led_speedup\":{:.3},\"tt_speedup\":{:.3},\
         \"led_agreement\":{:.3},\"tt_agreement\":{:.3},\
         \"dense_bytes\":{},\"led_bytes\":{},\"tt_bytes\":{},\
         \"led_compression\":{:.4},\"tt_compression\":{:.4}}}",
        panel.prompts,
        panel.new_tokens,
        dense.tokens_per_sec,
        led.tokens_per_sec,
        tt.tokens_per_sec,
        led.speedup,
        tt.speedup,
        led.agreement,
        tt.agreement,
        dense.bytes,
        led.bytes,
        tt.bytes,
        led.compression,
        tt.compression,
    );
}
