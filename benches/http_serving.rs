//! End-to-end HTTP serving bench: classify throughput through the real
//! socket (connect + HTTP parse + registry resolve + dispatch per request,
//! `Connection: close` semantics) for the dense and LED checkpoints of one
//! registered model — what an external client actually pays, as opposed to
//! `native_serving`'s in-process handle numbers.
//!
//! Runs hermetically on a loopback ephemeral port and prints a
//! machine-readable `BENCH_HTTP {...}` JSON line.
//!
//! Env: GREENFORMER_BENCH_HTTP_REQUESTS (default 128) scales the run.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use greenformer::backend::native::{demo_variants, TextModelCfg};
use greenformer::coordinator::{BatcherConfig, RoutePolicy, ServeConfig};
use greenformer::eval::measure_http_serving;
use greenformer::registry::ModelRegistry;
use greenformer::serve_http::{HttpConfig, HttpServer};

const CLIENTS: usize = 8;
const MAX_BATCH: usize = 8;

fn main() {
    let requests: usize = std::env::var("GREENFORMER_BENCH_HTTP_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(128);

    let cfg = TextModelCfg::default();
    let (dense, led25) = demo_variants(&cfg, 42, 0.25).expect("variants");
    let mut variants = HashMap::new();
    variants.insert("dense".to_string(), dense);
    variants.insert("led_r25".to_string(), led25);

    let serve_cfg = ServeConfig::with_batcher(
        BatcherConfig { max_batch: MAX_BATCH, max_wait: Duration::from_millis(2) },
        4096,
    );
    let registry = Arc::new(ModelRegistry::with_serve_config(serve_cfg));
    // Quality/balanced stay on dense; the fast tier rides the LED factors.
    let route = RoutePolicy::Tiered {
        quality: "dense".to_string(),
        balanced: "dense".to_string(),
        fast: "led_r25".to_string(),
    };
    registry
        .install_local("bench", "text", "v1", "dense", variants, Some(route))
        .expect("install bench model");

    let server =
        HttpServer::bind("127.0.0.1:0", registry, HttpConfig::default()).expect("bind http");
    let addr = server.local_addr();

    let tokens: Vec<i32> = (0..cfg.seq as i32).map(|i| i % cfg.vocab as i32).collect();
    let body_for = |tier: &str| format!("{{\"tokens\":{tokens:?},\"tier\":\"{tier}\"}}");

    println!(
        "== http serving: dense vs LED over loopback (clients={CLIENTS}, batch={MAX_BATCH}, \
         requests={requests}, d={} ff={} seq={}) ==",
        cfg.d, cfg.ff, cfg.seq
    );
    println!("{:<10} {:>10} {:>10} {:>10} {:>6}", "tier", "req/s", "p50(us)", "p95(us)", "ok");

    // Warm the dispatcher + thread pool outside the measured runs.
    measure_http_serving(addr, &body_for("quality"), MAX_BATCH, CLIENTS).expect("warmup");

    let dense_stats = measure_http_serving(addr, &body_for("quality"), requests, CLIENTS)
        .expect("dense run");
    println!(
        "{:<10} {:>10.1} {:>10} {:>10} {:>6}",
        "quality", dense_stats.rps, dense_stats.p50_us, dense_stats.p95_us, dense_stats.ok
    );
    let led_stats =
        measure_http_serving(addr, &body_for("fast"), requests, CLIENTS).expect("led run");
    println!(
        "{:<10} {:>10.1} {:>10} {:>10} {:>6}",
        "fast", led_stats.rps, led_stats.p50_us, led_stats.p95_us, led_stats.ok
    );

    assert_eq!(dense_stats.ok, requests, "dense run had non-2xx replies");
    assert_eq!(led_stats.ok, requests, "led run had non-2xx replies");

    println!("speedup vs dense: led_r25 {:.2}x", led_stats.rps / dense_stats.rps);
    println!(
        "BENCH_HTTP {{\"requests\":{requests},\"clients\":{CLIENTS},\
         \"dense_rps\":{:.2},\"led_r25_rps\":{:.2},\
         \"dense_p50_us\":{},\"dense_p95_us\":{},\"led_r25_p50_us\":{},\"led_r25_p95_us\":{},\
         \"led_r25_speedup\":{:.3}}}",
        dense_stats.rps,
        led_stats.rps,
        dense_stats.p50_us,
        dense_stats.p95_us,
        led_stats.p50_us,
        led_stats.p95_us,
        led_stats.rps / dense_stats.rps
    );

    server.shutdown().expect("clean shutdown");
}
