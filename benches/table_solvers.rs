//! E6 — solver comparison: reconstruction error and wall-clock per solver
//! across ratios, on a trained-like (decaying-spectrum) weight. Demonstrates
//! the paper's claim that Random is unsuitable post-training while SVD/SNMF
//! approximate well.

use greenformer::experiments::tables::{render_solver_table, solver_table, trained_like_matrix};
use greenformer::factorize::Solver;
use greenformer::util::Bench;

fn main() {
    let rows = solver_table(&[0.10, 0.25, 0.50, 0.75], 50);
    println!("\n== E6: solvers ==\n{}", render_solver_table(&rows));

    let w = trained_like_matrix(128, 512, 1.0, 7);
    let mut bench = Bench::new("solver_128x512_r32");
    bench.max_iters = 15;
    for solver in [Solver::Random, Solver::Svd, Solver::Snmf] {
        bench.bench(&solver.to_string(), || solver.factorize(&w, 32, 50, 11));
    }
}
