//! E7 — kernel-level speedup: dense GEMM vs the factorized (LED) product at
//! paper-relevant shapes, in the Rust substrate (the same ratio the Pallas
//! kernel realizes on TPU; the analytical TPU estimate is printed alongside).

use greenformer::flops::roofline::led_tpu_speedup_estimate;
use greenformer::linalg::Matrix;
use greenformer::util::{Bench, Pcg64};

fn main() {
    let shapes: &[(&str, usize, usize, usize)] = &[
        // (label, k, n, r) at tokens = 256
        ("text_dd_r32", 128, 128, 32),
        ("bert_attn_r192", 768, 768, 192),
        ("bert_ffn_r152", 768, 3072, 152),
        ("bert_ffn_r304", 768, 3072, 304),
    ];
    let tokens = 256;
    println!("\n== E7: analytical TPU estimates (tokens=256) ==");
    for &(label, k, n, r) in shapes {
        println!(
            "  {label}: flops-speedup={:.2}x tpu-est={:.2}x",
            greenformer::flops::led_speedup(k, n, r),
            led_tpu_speedup_estimate(tokens, k, r, n)
        );
    }

    let mut rng = Pcg64::seeded(1);
    let mut bench = Bench::new("gemm_dense_vs_led");
    bench.max_iters = 30;
    for &(label, k, n, r) in shapes {
        let x = Matrix::randn(tokens, k, 1.0, &mut rng);
        let w = Matrix::randn(k, n, 1.0, &mut rng);
        let a = Matrix::randn(k, r, 1.0, &mut rng);
        let b = Matrix::randn(r, n, 1.0, &mut rng);
        bench.bench(&format!("dense/{label}"), || x.matmul(&w));
        bench.bench(&format!("led/{label}"), || x.matmul(&a).matmul(&b));
        if let Some(s) = bench.speedup(&format!("dense/{label}"), &format!("led/{label}")) {
            println!("    -> measured CPU speedup {label}: {s:.2}x");
        }
    }
}
