//! E7 — kernel-level speedup: dense GEMM vs the factorized (LED) product at
//! paper-relevant shapes, in the Rust substrate (the same ratio the Pallas
//! kernel realizes on TPU; the analytical TPU estimate is printed alongside).
//!
//! Since PR 5 this bench also reports **old-vs-new kernel** GFLOP/s: the
//! pre-PR-5 serial i-k-j loop is kept as `matmul_into_reference` and timed
//! against the packed, pool-parallel `matmul_into` (plus the column-split
//! GEMV at the batch-1 decode shape), so the kernel-layer speedup is
//! *measured* on every run — emitted as a machine-readable
//! `BENCH_KERNELS {...}` JSON line that `python/tools/collect_bench.py`
//! persists into `BENCH_KERNELS.json`.

use greenformer::flops::roofline::led_tpu_speedup_estimate;
use greenformer::linalg::{matmul_into, matmul_into_reference, Matrix};
use greenformer::util::{Bench, Pcg64};

/// GFLOP/s for an (m, k, n) GEMM at `secs` per iteration.
fn gflops(m: usize, k: usize, n: usize, secs: f64) -> f64 {
    (2.0 * m as f64 * k as f64 * n as f64) / secs / 1e9
}

struct KernelRow {
    label: String,
    m: usize,
    k: usize,
    n: usize,
    ref_gflops: f64,
    new_gflops: f64,
}

fn main() {
    let shapes: &[(&str, usize, usize, usize)] = &[
        // (label, k, n, r) at tokens = 256
        ("text_dd_r32", 128, 128, 32),
        ("bert_attn_r192", 768, 768, 192),
        ("bert_ffn_r152", 768, 3072, 152),
        ("bert_ffn_r304", 768, 3072, 304),
    ];
    let tokens = 256;
    println!("\n== E7: analytical TPU estimates (tokens=256) ==");
    for &(label, k, n, r) in shapes {
        println!(
            "  {label}: flops-speedup={:.2}x tpu-est={:.2}x",
            greenformer::flops::led_speedup(k, n, r),
            led_tpu_speedup_estimate(tokens, k, r, n)
        );
    }

    let mut rng = Pcg64::seeded(1);
    let mut bench = Bench::new("gemm_dense_vs_led");
    bench.max_iters = 30;
    for &(label, k, n, r) in shapes {
        let x = Matrix::randn(tokens, k, 1.0, &mut rng);
        let w = Matrix::randn(k, n, 1.0, &mut rng);
        let a = Matrix::randn(k, r, 1.0, &mut rng);
        let b = Matrix::randn(r, n, 1.0, &mut rng);
        bench.bench(&format!("dense/{label}"), || x.matmul(&w));
        bench.bench(&format!("led/{label}"), || x.matmul(&a).matmul(&b));
        if let Some(s) = bench.speedup(&format!("dense/{label}"), &format!("led/{label}")) {
            println!("    -> measured CPU speedup {label}: {s:.2}x");
        }
    }

    // ---------------------------------------------------------------------
    // Old vs new kernel layer: legacy serial baseline vs packed/pooled GEMM
    // and the m=1 decode GEMV. Same inputs, same accumulation order — the
    // delta is pure kernel engineering.
    // ---------------------------------------------------------------------
    println!("\n== kernel layer: legacy serial vs packed parallel ==");
    let mut bench = Bench::new("kernels_old_vs_new");
    bench.max_iters = 20;
    let gemm_shapes: &[(&str, usize, usize, usize)] = &[
        ("gemm_256x768x768", 256, 768, 768),
        ("gemm_256x768x3072", 256, 768, 3072),
        ("gemm_256x128x128", 256, 128, 128),
        ("gemv_1x768x3072", 1, 768, 3072),
        ("gemv_1x192x768", 1, 192, 768),
    ];
    let mut rows: Vec<KernelRow> = Vec::new();
    for &(label, m, k, n) in gemm_shapes {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let mut out = vec![0.0f32; m * n];
        let old = bench.bench(&format!("old/{label}"), || {
            out.iter_mut().for_each(|v| *v = 0.0);
            matmul_into_reference(m, k, n, &a.data, &b.data, &mut out);
            std::hint::black_box(out[0])
        });
        let new = bench.bench(&format!("new/{label}"), || {
            out.iter_mut().for_each(|v| *v = 0.0);
            matmul_into(m, k, n, &a.data, &b.data, &mut out);
            std::hint::black_box(out[0])
        });
        if let (Some(old), Some(new)) = (old, new) {
            let row = KernelRow {
                label: label.to_string(),
                m,
                k,
                n,
                ref_gflops: gflops(m, k, n, old.median_s),
                new_gflops: gflops(m, k, n, new.median_s),
            };
            println!(
                "    -> {label}: old {:.2} GFLOP/s  new {:.2} GFLOP/s  ({:.2}x)",
                row.ref_gflops,
                row.new_gflops,
                row.new_gflops / row.ref_gflops
            );
            rows.push(row);
        }
    }

    if !rows.is_empty() {
        let cases: Vec<String> = rows
            .iter()
            .map(|r| {
                format!(
                    "{{\"label\":\"{}\",\"m\":{},\"k\":{},\"n\":{},\"ref_gflops\":{:.3},\
                     \"new_gflops\":{:.3},\"speedup\":{:.3}}}",
                    r.label,
                    r.m,
                    r.k,
                    r.n,
                    r.ref_gflops,
                    r.new_gflops,
                    r.new_gflops / r.ref_gflops
                )
            })
            .collect();
        println!("BENCH_KERNELS {{\"cases\":[{}]}}", cases.join(","));
    }
}
