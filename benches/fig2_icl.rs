//! E3 — Figure 2 (right): in-context-learning factorization.
//!
//! Pretrains the tiny causal LM on the ICL corpus once, then regenerates the
//! panel (SVD-factorize the pretrained LM at each ratio, k-shot eval), and
//! times the batched LM forward (dense vs led_r25) — the serving hot path.
//!
//! Full panel: `GREENFORMER_STEPS=600 GREENFORMER_EVAL=256 cargo bench --bench fig2_icl`

use greenformer::data::lm::LmCorpus;
use greenformer::experiments::{icl, ExpParams, FigEnv};
use greenformer::factorize::{auto_fact, AutoFactConfig, Rank, Solver};
use greenformer::runtime::Engine;
use greenformer::train::Trainer;
use greenformer::util::Bench;

fn main() {
    let Ok(engine) = Engine::load_default() else {
        eprintln!("SKIP fig2_icl bench: AOT artifacts / PJRT runtime unavailable");
        return;
    };
    let params = ExpParams::quick();
    let pretrain_steps = std::env::var("GREENFORMER_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(120);

    // Pretrain once; reuse across panel + timing series.
    let mut trainer = Trainer::from_init(&engine, "lm", "dense").unwrap();
    let corpus = LmCorpus::new(128, params.seed);
    trainer.train_lm(&corpus, pretrain_steps, |_| {}).unwrap();
    let lm_params = trainer.params.clone();

    let result =
        icl(&FigEnv::Pjrt(&engine), &params, Some(lm_params.clone()), 0).expect("icl harness");
    println!("\n{}", result.render());

    // Timing series: one batched LM forward, dense vs factorized.
    let mut fact = lm_params.clone();
    auto_fact(
        &mut fact,
        &AutoFactConfig {
            rank: Rank::Ratio(0.25),
            solver: Solver::Svd,
            num_iter: 20,
            submodules: None,
            ..Default::default()
        },
    )
    .unwrap();
    let toks = corpus.batch(0, 4);
    let mut bench = Bench::new("lm_forward_b4");
    bench.max_iters = 20;
    let dense_graph = engine.manifest().find("lm", "dense", "fwd", Some(4)).unwrap().clone();
    bench.bench("dense", || {
        engine.run_fwd(&dense_graph, &lm_params, &[toks.clone()]).unwrap()
    });
    let fact_graph = engine.manifest().find("lm", "led_r25", "fwd", Some(4)).unwrap().clone();
    bench.bench("led_r25", || {
        engine.run_fwd(&fact_graph, &fact, &[toks.clone()]).unwrap()
    });
    if let Some(s) = bench.speedup("dense", "led_r25") {
        println!("lm fwd speedup led_r25 vs dense: {s:.2}x");
    }
}
