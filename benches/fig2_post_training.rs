//! E2 — Figure 2 (center): post-training factorization.
//!
//! Regenerates the panel (train dense → auto_fact at each ratio → eval) and
//! times the post-training factorization itself (auto_fact with SVD vs SNMF
//! vs Random over the text init checkpoint).
//!
//! Full panel: `GREENFORMER_STEPS=300 GREENFORMER_EVAL=256 cargo bench --bench fig2_post_training`

use greenformer::experiments::{post_training, ExpParams, FigEnv};
use greenformer::factorize::{auto_fact, AutoFactConfig, Rank, Solver};
use greenformer::runtime::Engine;
use greenformer::tensor::ParamStore;
use greenformer::util::Bench;

fn main() {
    let Ok(engine) = Engine::load_default() else {
        eprintln!("SKIP fig2_post_training bench: AOT artifacts / PJRT runtime unavailable");
        return;
    };
    let params = ExpParams::quick();

    let result =
        post_training(&FigEnv::Pjrt(&engine), &params, Solver::Svd).expect("post-training harness");
    println!("\n{}", result.render());

    // Timing series: auto_fact latency per solver on the text init.
    let ckpt = engine.manifest().checkpoint("text", "dense").unwrap();
    let base = ParamStore::load_gtz(ckpt).unwrap();
    let mut bench = Bench::new("auto_fact_text_model");
    bench.max_iters = 10;
    for solver in [Solver::Svd, Solver::Snmf, Solver::Random] {
        bench.bench(&solver.to_string(), || {
            let mut p = base.clone();
            auto_fact(
                &mut p,
                &AutoFactConfig {
                    rank: Rank::Ratio(0.25),
                    solver,
                    num_iter: 20,
                    submodules: None,
                    ..Default::default()
                },
            )
            .unwrap()
        });
    }
}
