//! Artifact-free KV-cached decode bench: dense vs LED variants of the
//! synthetic causal LM through the native backend's incremental-decoding
//! path (`DecodeSession` + `run_decode_step`).
//!
//! Measures the two numbers that price a generation server — prefill wall
//! time and single-token decode latency (p50/p95 + tokens/sec) — for the
//! dense checkpoint and its Ratio(0.5)/Ratio(0.25) LED factorizations.
//! Decode steps are matvec-bound, so the LED rank reduction lands directly
//! on the per-token hot path: this is Figure 2's speedup axis where
//! production inference actually spends its time. Runs hermetically (no
//! artifacts, no PJRT) and prints a machine-readable
//! `BENCH_NATIVE_DECODE {...}` JSON line.
//!
//! Also measures the continuous-batching win: aggregate tokens/sec of
//! decoding N concurrent streams with stacked batched steps (one GEMM per
//! token step) vs round-robin solo steps (one GEMV chain per stream) — the
//! scheduler change SERVING.md documents.
//!
//! And the speculative-decoding win: the dense target decoded plain vs
//! drafted by its own SVD LED factorization (`build_draft_params`) and
//! verified k tokens per stacked pass — reporting `spec_tps`,
//! `spec_speedup` and `acceptance_rate` (the fraction of cheap drafts the
//! dense model accepted, the paper's accuracy-retention claim as a serving
//! number).
//!
//! Env: GREENFORMER_BENCH_DECODE_TOKENS (default 48) scales the generation
//! length; GREENFORMER_BENCH_DECODE_ITERS (default 3) the repetitions;
//! GREENFORMER_BENCH_DECODE_SESSIONS (default 8) the concurrent streams in
//! the batched-vs-roundrobin comparison; GREENFORMER_BENCH_SPEC_K (default
//! 4) the per-round draft length of the speculative comparison.

use greenformer::backend::native::{demo_variants, synth_fwd_graph, TextModelCfg};
use greenformer::backend::{build_draft_params, NativeBackend, SpecConfig};
use greenformer::eval::{
    measure_batched_decode, measure_decode_latency, measure_spec_decode, BatchedDecodeThroughput,
};
use greenformer::tensor::ParamStore;
use greenformer::util::Pcg64;

const PROMPT_TOKENS: usize = 16;

struct DecodeStats {
    name: String,
    tokens_per_sec: f64,
    prefill_ms: f64,
    p50_us: f64,
    p95_us: f64,
}

fn bench_variant(
    name: &str,
    store: &ParamStore,
    prompt: &[i32],
    new_tokens: usize,
    iters: usize,
) -> DecodeStats {
    let graph = synth_fwd_graph("lm", name, 1, store).expect("synth graph");
    let lat = measure_decode_latency(
        &NativeBackend::new(),
        &graph,
        store,
        prompt,
        new_tokens,
        1,
        iters,
    )
    .expect("measure_decode_latency");
    DecodeStats {
        name: name.to_string(),
        tokens_per_sec: lat.tokens_per_sec,
        prefill_ms: lat.prefill_s * 1e3,
        p50_us: lat.per_token_p50_s * 1e6,
        p95_us: lat.per_token_p95_s * 1e6,
    }
}

fn bench_batched(
    name: &str,
    store: &ParamStore,
    vocab: usize,
    sessions: usize,
    new_tokens: usize,
    iters: usize,
) -> BatchedDecodeThroughput {
    let graph = synth_fwd_graph("lm", name, 1, store).expect("synth graph");
    // Distinct prompts per stream (seeded off the stream index) so the
    // batch carries genuinely independent KV caches.
    let prompts: Vec<Vec<i32>> = (0..sessions)
        .map(|i| {
            let mut rng = Pcg64::new(100 + i as u64, 13);
            (0..PROMPT_TOKENS).map(|_| rng.below(vocab) as i32).collect()
        })
        .collect();
    measure_batched_decode(
        &NativeBackend::new(),
        &graph,
        store,
        &prompts,
        new_tokens,
        1,
        iters,
    )
    .expect("measure_batched_decode")
}

fn main() {
    let env_usize = |key: &str, default: usize| {
        std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
    };
    let iters = env_usize("GREENFORMER_BENCH_DECODE_ITERS", 3).max(1);
    let sessions = env_usize("GREENFORMER_BENCH_DECODE_SESSIONS", 8).max(2);
    let cfg = TextModelCfg::lm_default();
    let new_tokens = env_usize("GREENFORMER_BENCH_DECODE_TOKENS", 48)
        .clamp(1, cfg.seq - PROMPT_TOKENS);

    // Same seed → identical dense checkpoint across both ratio calls.
    let (dense, led50) = demo_variants(&cfg, 42, 0.5).expect("variants");
    let (_, led25) = demo_variants(&cfg, 42, 0.25).expect("variants");
    let mut rng = Pcg64::seeded(7);
    let prompt: Vec<i32> = (0..PROMPT_TOKENS).map(|_| rng.below(cfg.vocab) as i32).collect();

    println!(
        "== native decode: dense vs LED (d={} ff={} layers={} vocab={}, prompt={PROMPT_TOKENS}, \
         new={new_tokens}, iters={iters}) ==",
        cfg.d, cfg.ff, cfg.layers, cfg.vocab
    );
    println!(
        "{:<10} {:>10} {:>12} {:>12} {:>12}",
        "variant", "tok/s", "prefill(ms)", "p50(us/tok)", "p95(us/tok)"
    );

    let cases = [("dense", &dense), ("led_r50", &led50), ("led_r25", &led25)];
    let mut stats = Vec::new();
    for (name, store) in cases {
        let s = bench_variant(name, store, &prompt, new_tokens, iters);
        println!(
            "{:<10} {:>10.1} {:>12.2} {:>12.1} {:>12.1}",
            s.name, s.tokens_per_sec, s.prefill_ms, s.p50_us, s.p95_us
        );
        stats.push(s);
    }

    let get = |n: &str| stats.iter().find(|s| s.name == n).expect("stat");
    let (d, r50, r25) = (get("dense"), get("led_r50"), get("led_r25"));
    println!(
        "decode speedup vs dense: led_r50 {:.2}x  led_r25 {:.2}x",
        r50.tokens_per_sec / d.tokens_per_sec,
        r25.tokens_per_sec / d.tokens_per_sec
    );

    // Continuous batching: N concurrent streams, stacked step vs round-robin.
    println!(
        "\n== continuous batching: {sessions} streams, stacked step vs round-robin =="
    );
    println!(
        "{:<10} {:>14} {:>16} {:>10}",
        "variant", "batched(tok/s)", "roundrobin(tok/s)", "speedup"
    );
    let db = bench_batched("dense", &dense, cfg.vocab, sessions, new_tokens, iters);
    println!(
        "{:<10} {:>14.1} {:>16.1} {:>9.2}x",
        "dense", db.batched_tps, db.roundrobin_tps, db.speedup()
    );
    let lb = bench_batched("led_r25", &led25, cfg.vocab, sessions, new_tokens, iters);
    println!(
        "{:<10} {:>14.1} {:>16.1} {:>9.2}x",
        "led_r25", lb.batched_tps, lb.roundrobin_tps, lb.speedup()
    );

    // Speculative decoding: dense target, SVD LED draft of itself at r25.
    // (The LED variants above use the Random solver for shape realism; the
    // draft must *approximate* the target, so it gets the SVD path.)
    let spec_k = env_usize("GREENFORMER_BENCH_SPEC_K", 4).max(1);
    let spec = SpecConfig { draft_ratio: 0.25, k: spec_k, ..Default::default() };
    let draft = build_draft_params(&dense, spec.draft_ratio).expect("draft factorization");
    let dense_graph = synth_fwd_graph("lm", "dense", 1, &dense).expect("synth graph");
    let sp = measure_spec_decode(
        &NativeBackend::new(),
        &dense_graph,
        &dense,
        &draft,
        &prompt,
        new_tokens,
        &spec,
        1,
        iters,
    )
    .expect("measure_spec_decode");
    println!(
        "\n== speculative decoding: dense target, SVD LED draft r25, k={spec_k} =="
    );
    println!(
        "{:<10} {:>12} {:>12} {:>10} {:>12}",
        "schedule", "spec(tok/s)", "plain(tok/s)", "speedup", "acceptance"
    );
    println!(
        "{:<10} {:>12.1} {:>12.1} {:>9.2}x {:>12.2}",
        "greedy",
        sp.spec_tps,
        sp.plain_tps,
        sp.speedup(),
        sp.acceptance_rate
    );

    println!(
        "BENCH_NATIVE_DECODE {{\"prompt_tokens\":{PROMPT_TOKENS},\"new_tokens\":{new_tokens},\
         \"iters\":{iters},\"dense_tps\":{:.2},\"led_r50_tps\":{:.2},\"led_r25_tps\":{:.2},\
         \"dense_prefill_ms\":{:.3},\"led_r50_prefill_ms\":{:.3},\"led_r25_prefill_ms\":{:.3},\
         \"dense_p50_us\":{:.1},\"dense_p95_us\":{:.1},\"led_r50_p50_us\":{:.1},\
         \"led_r50_p95_us\":{:.1},\"led_r25_p50_us\":{:.1},\"led_r25_p95_us\":{:.1},\
         \"led_r50_speedup\":{:.3},\"led_r25_speedup\":{:.3},\
         \"batch_sessions\":{sessions},\
         \"dense_batched_tps\":{:.2},\"dense_roundrobin_tps\":{:.2},\
         \"dense_batched_speedup\":{:.3},\
         \"led_r25_batched_tps\":{:.2},\"led_r25_roundrobin_tps\":{:.2},\
         \"led_r25_batched_speedup\":{:.3},\
         \"spec_k\":{spec_k},\"spec_tps\":{:.2},\"spec_plain_tps\":{:.2},\
         \"spec_speedup\":{:.3},\"acceptance_rate\":{:.3}}}",
        d.tokens_per_sec,
        r50.tokens_per_sec,
        r25.tokens_per_sec,
        d.prefill_ms,
        r50.prefill_ms,
        r25.prefill_ms,
        d.p50_us,
        d.p95_us,
        r50.p50_us,
        r50.p95_us,
        r25.p50_us,
        r25.p95_us,
        r50.tokens_per_sec / d.tokens_per_sec,
        r25.tokens_per_sec / d.tokens_per_sec,
        db.batched_tps,
        db.roundrobin_tps,
        db.speedup(),
        lb.batched_tps,
        lb.roundrobin_tps,
        lb.speedup(),
        sp.spec_tps,
        sp.plain_tps,
        sp.speedup(),
        sp.acceptance_rate
    );
}
