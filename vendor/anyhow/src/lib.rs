//! Offline, API-compatible subset of the `anyhow` crate.
//!
//! The build environment has no network and no registry cache, so the
//! workspace vendors the small part of `anyhow` it actually uses as a plain
//! path crate (see DESIGN.md §7). Provided surface:
//!
//! * [`Error`] — a context chain with `Display` (`{}` shows the outermost
//!   message, `{:#}` the full `outer: inner: ...` chain) and an
//!   anyhow-style multi-line `Debug`.
//! * [`Result<T>`] with the error type defaulted to [`Error`].
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros.
//! * [`Context`] — `.context(...)` / `.with_context(...)` on any
//!   `Result<T, E>` whose error converts into [`Error`] (std errors via the
//!   blanket `From`, and `Error` itself).
//!
//! Like the real crate, `Error` deliberately does not implement
//! `std::error::Error`: that is what makes the blanket
//! `impl<E: std::error::Error> From<E> for Error` coherent.

use std::fmt;

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-chain error: `chain[0]` is the outermost message/context, the
/// rest are the causes from outer to inner.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages from outermost to innermost.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

/// Every std error converts, capturing its `source()` chain. (Coherent with
/// the reflexive `From<T> for T` because `Error: !std::error::Error`.)
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `.context(...)` / `.with_context(...)` on fallible results.
pub trait Context<T, E> {
    /// Wrap the error value, if any, with the given context message.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    /// Wrap the error value, if any, with a lazily evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string (with implicit captures),
/// a single printable value, or format arguments.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*).into())
    };
}

/// Return early with an [`anyhow!`] error when the condition fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn macro_forms() {
        let x = 3;
        assert_eq!(anyhow!("plain").to_string(), "plain");
        assert_eq!(anyhow!("captured {x}").to_string(), "captured 3");
        assert_eq!(anyhow!("args {} {}", 1, 2).to_string(), "args 1 2");
        let s = String::from("owned");
        assert_eq!(anyhow!(s).to_string(), "owned");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            if !flag {
                bail!("unreachable");
            }
            Ok(7)
        }
        assert_eq!(f(true).unwrap(), 7);
        assert_eq!(f(false).unwrap_err().to_string(), "flag was false");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i64> {
            Ok(s.parse::<i64>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn context_on_std_and_anyhow_errors() {
        let e: Result<()> = Err(io_err()).context("reading file");
        let e = e.unwrap_err();
        assert_eq!(e.to_string(), "reading file");
        assert_eq!(format!("{e:#}"), "reading file: gone");

        let inner: Result<()> = Err(anyhow!("inner"));
        let outer = inner.with_context(|| format!("outer {}", 1)).unwrap_err();
        assert_eq!(format!("{outer:#}"), "outer 1: inner");
        assert_eq!(outer.root_cause(), "inner");
        assert_eq!(outer.chain().count(), 2);
    }

    #[test]
    fn debug_renders_cause_chain() {
        let e: Result<()> = Err(io_err()).context("ctx");
        let text = format!("{:?}", e.unwrap_err());
        assert!(text.starts_with("ctx"));
        assert!(text.contains("Caused by:"));
        assert!(text.contains("gone"));
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync + 'static>() {}
        assert_send_sync::<Error>();
    }
}
