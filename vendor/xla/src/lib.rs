//! Types-only offline stub of the PJRT/XLA bindings.
//!
//! The real backend (`xla_extension` over the PJRT C API) is unavailable in
//! the offline build environment, so this crate provides the exact type
//! surface `greenformer::runtime::engine` compiles against:
//!
//! * Host-side [`Literal`] marshalling is **fully functional** (shape +
//!   dtype + little-endian bytes), so tensor↔literal round-trips and their
//!   tests work without any XLA installation.
//! * Device plumbing ([`PjRtClient::cpu`], compilation, execution) returns
//!   a clear "PJRT runtime unavailable" error; everything that needs a real
//!   device skips gracefully on that error (see DESIGN.md §7).
//!
//! Swapping in the real bindings is a one-line change in the workspace
//! manifest; the API below mirrors the `xla` crate that wraps
//! `xla_extension` 0.5.x.
//!
//! Like the real PJRT wrapper, the client and executable types are
//! `Rc`-based and therefore `!Send`: each thread that executes graphs must
//! own its client (the coordinator relies on this discipline).

use std::borrow::Borrow;
use std::fmt;
use std::marker::PhantomData;
use std::path::Path;
use std::rc::Rc;

/// Stub error: a message, `Display`able into the caller's `anyhow` chain.
#[derive(Clone, Debug)]
pub struct Error(String);

impl Error {
    fn new(msg: impl Into<String>) -> Self {
        Error(msg.into())
    }

    fn unavailable(what: &str) -> Self {
        Error(format!(
            "{what}: PJRT runtime unavailable (offline `xla` stub; link the real \
             xla_extension bindings to execute graphs)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// XLA element types (subset + headroom; matches PJRT's primitive types).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[non_exhaustive]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    U8,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
}

impl ElementType {
    /// Bytes per element, when fixed-width.
    pub fn size_bytes(self) -> usize {
        match self {
            ElementType::Pred | ElementType::S8 | ElementType::U8 => 1,
            ElementType::F16 | ElementType::Bf16 => 2,
            ElementType::S32 | ElementType::U32 | ElementType::F32 => 4,
            ElementType::S64 | ElementType::U64 | ElementType::F64 => 8,
        }
    }
}

mod sealed {
    pub trait Sealed {}
}

/// Rust scalar types that map onto an [`ElementType`].
pub trait NativeType: Copy + sealed::Sealed {
    const TY: ElementType;
    fn from_le_bytes(bytes: &[u8]) -> Self;
}

macro_rules! native {
    ($t:ty, $ty:expr) => {
        impl sealed::Sealed for $t {}
        impl NativeType for $t {
            const TY: ElementType = $ty;
            fn from_le_bytes(bytes: &[u8]) -> Self {
                <$t>::from_le_bytes(bytes.try_into().expect("element width"))
            }
        }
    };
}

native!(f32, ElementType::F32);
native!(f64, ElementType::F64);
native!(i32, ElementType::S32);
native!(i64, ElementType::S64);
native!(u32, ElementType::U32);
native!(u64, ElementType::U64);

/// Shape of an array literal: element type + dimensions.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn ty(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

#[derive(Clone, Debug)]
enum Repr {
    Array {
        ty: ElementType,
        dims: Vec<i64>,
        data: Vec<u8>,
    },
    Tuple(Vec<Literal>),
}

/// A host-side literal: dense array bytes or a tuple of literals. Fully
/// functional (this is what the marshalling tests exercise).
#[derive(Clone, Debug)]
pub struct Literal {
    repr: Repr,
}

impl Literal {
    /// Build an array literal from a shape and raw little-endian bytes.
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        untyped_data: &[u8],
    ) -> Result<Literal> {
        let numel: usize = dims.iter().product();
        let want = numel * ty.size_bytes();
        if untyped_data.len() != want {
            return Err(Error::new(format!(
                "literal data is {} bytes but shape {dims:?} of {ty:?} needs {want}",
                untyped_data.len()
            )));
        }
        Ok(Literal {
            repr: Repr::Array {
                ty,
                dims: dims.iter().map(|&d| d as i64).collect(),
                data: untyped_data.to_vec(),
            },
        })
    }

    /// Build a tuple literal (what executables return with `return_tuple`).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal {
            repr: Repr::Tuple(parts),
        }
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match &self.repr {
            Repr::Array { ty, dims, .. } => Ok(ArrayShape {
                ty: *ty,
                dims: dims.clone(),
            }),
            Repr::Tuple(_) => Err(Error::new("tuple literal has no array shape")),
        }
    }

    /// Raw little-endian bytes of an array literal.
    pub fn raw_bytes(&self) -> Result<&[u8]> {
        match &self.repr {
            Repr::Array { data, .. } => Ok(data),
            Repr::Tuple(_) => Err(Error::new("tuple literal has no raw bytes")),
        }
    }

    /// Decode an array literal into a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match &self.repr {
            Repr::Array { ty, data, .. } => {
                if *ty != T::TY {
                    return Err(Error::new(format!(
                        "literal is {ty:?}, requested {:?}",
                        T::TY
                    )));
                }
                let width = ty.size_bytes();
                Ok(data.chunks_exact(width).map(T::from_le_bytes).collect())
            }
            Repr::Tuple(_) => Err(Error::new("tuple literal cannot convert to a vector")),
        }
    }

    /// Decompose a tuple literal into its parts.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.repr {
            Repr::Tuple(parts) => Ok(parts),
            Repr::Array { .. } => Err(Error::new("array literal is not a tuple")),
        }
    }
}

/// Parsed HLO module text (held verbatim; the stub cannot compile it).
#[derive(Clone, Debug)]
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::new(format!("reading HLO text {path:?}: {e}")))?;
        Ok(HloModuleProto { text })
    }

    pub fn text(&self) -> &str {
        &self.text
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Clone, Debug)]
pub struct XlaComputation {
    _proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            _proto: proto.clone(),
        }
    }
}

/// A device-resident buffer produced by an execution.
pub struct PjRtBuffer {
    literal: Literal,
    _not_send: PhantomData<Rc<()>>,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// A compiled executable. `!Send`, like the real `Rc`-based wrapper.
pub struct PjRtLoadedExecutable {
    _not_send: PhantomData<Rc<()>>,
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("execute"))
    }
}

/// A PJRT client. `!Send`: each executing thread owns its own client.
pub struct PjRtClient {
    _not_send: PhantomData<Rc<()>>,
}

impl PjRtClient {
    /// The offline stub has no PJRT plugin, so client creation fails with a
    /// descriptive error; callers treat that as "runtime unavailable" and
    /// skip device work.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let mk = Literal::create_from_shape_and_untyped_data;
        let data = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit = mk(ElementType::F32, &[3], &bytes).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(shape.dims(), &[3]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_rejects_wrong_byte_count() {
        let mk = Literal::create_from_shape_and_untyped_data;
        assert!(mk(ElementType::S32, &[2], &[0u8; 7]).is_err());
    }

    #[test]
    fn tuple_decomposes() {
        let mk = Literal::create_from_shape_and_untyped_data;
        let a = mk(ElementType::S32, &[1], &[1, 0, 0, 0]).unwrap();
        let t = Literal::tuple(vec![a.clone(), a]);
        assert!(t.array_shape().is_err());
        assert_eq!(t.to_tuple().unwrap().len(), 2);
    }

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err().to_string();
        assert!(err.contains("unavailable"), "{err}");
    }
}
