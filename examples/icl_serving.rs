//! ICL + serving demo (the paper's third use case, behind the coordinator).
//!
//! ```bash
//! make artifacts && cargo run --release --example icl_serving
//! ```
//!
//! 1. Pretrains the tiny causal LM on the synthetic ICL corpus.
//! 2. SVD-factorizes the pretrained LM (led_r50).
//! 3. Runs k-shot in-context evaluation on the three text tasks, dense vs
//!    factorized — no gradients anywhere, Python nowhere.
//! 4. Serves a concurrent classification request stream through the
//!    thread-based coordinator with variant routing, and prints metrics.
//!
//! Env: GREENFORMER_STEPS (LM pretrain steps, default 400).

use std::collections::HashMap;

use greenformer::coordinator::{serve_classifier, RoutePolicy, Router, ServeConfig, Tier};
use greenformer::data::lm::LmCorpus;
use greenformer::data::text::all_text_tasks;
use greenformer::data::{Dataset, Split};
use greenformer::eval::eval_icl;
use greenformer::factorize::{auto_fact, AutoFactConfig, Rank, Solver};
use greenformer::runtime::Engine;
use greenformer::train::Trainer;

fn main() -> greenformer::Result<()> {
    let steps: usize = std::env::var("GREENFORMER_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);
    let engine = Engine::load_default()?;

    // 1. Pretrain the LM on the ICL corpus.
    println!("=== pretraining lm/dense on the ICL corpus ({steps} steps) ===");
    let corpus = LmCorpus::new(128, 42);
    let mut trainer = Trainer::from_init(&engine, "lm", "dense")?;
    trainer.train_lm(&corpus, steps, |log| {
        if log.step % 25 == 0 {
            println!("  step {:>4}  lm loss {:.4}", log.step, log.loss);
        }
    })?;
    let dense = trainer.params.clone();

    // 2. Factorize the pretrained LM.
    let mut fact = dense.clone();
    let report = auto_fact(
        &mut fact,
        &AutoFactConfig {
            rank: Rank::Ratio(0.50),
            solver: Solver::Svd,
            num_iter: 50,
            submodules: None,
            ..Default::default()
        },
    )?;
    println!(
        "factorized LM: {} -> {} params ({} layers)",
        dense.n_params(),
        fact.n_params(),
        report.n_factorized()
    );

    // 3. k-shot ICL eval, dense vs factorized.
    let k = 4;
    println!("\n=== {k}-shot in-context learning ===");
    println!("task        dense-acc  led_r50-acc  speedup");
    let dense_g = engine.manifest().find("lm", "dense", "fwd", None)?.clone();
    let fact_g = engine.manifest().find("lm", "led_r50", "fwd", None)?.clone();
    for task in all_text_tasks(64, 42) {
        let ed = eval_icl(&engine, &dense_g, &dense, task.as_ref(), k, 128, 42)?;
        let ef = eval_icl(&engine, &fact_g, &fact, task.as_ref(), k, 128, 42)?;
        println!(
            "{:<11} {:.3}      {:.3}        {:.2}x",
            task.name(),
            ed.accuracy(),
            ef.accuracy(),
            ed.sec_per_batch / ef.sec_per_batch
        );
    }

    // 4. Serve a classification stream through the coordinator.
    println!("\n=== serving demo (adaptive routing, text classifier) ===");
    let mut stores = HashMap::new();
    for variant in ["dense", "led_r25"] {
        let mut t = Trainer::from_init(&engine, "text", variant)?;
        let ds = greenformer::data::text::PolarityTask::new(64, 42);
        t.train_classifier(&ds, 80, None, |_| {})?;
        stores.insert(variant.to_string(), t.params);
    }
    let router = Router::new(
        RoutePolicy::Tiered {
            quality: "dense".into(),
            balanced: "dense".into(),
            fast: "led_r25".into(),
        },
        stores.keys().cloned().collect(),
    )?;

    drop(engine); // the coordinator thread builds its own PJRT client
    let handle = serve_classifier(
        greenformer::artifacts_dir(),
        "text",
        stores,
        router,
        ServeConfig::default(),
    )?;
    let ds = greenformer::data::text::PolarityTask::new(64, 42);
    let mut joins = Vec::new();
    for i in 0..200usize {
        let h = handle.clone();
        let ex = ds.example(Split::Eval, i);
        joins.push(std::thread::spawn(move || {
            let tier = if i % 2 == 0 { Tier::Fast } else { Tier::Quality };
            let r = h.classify(ex.tokens, tier)?;
            Ok::<bool, anyhow::Error>(r.label == ex.label)
        }));
    }
    let mut correct = 0;
    for j in joins {
        correct += j.join().expect("client thread")? as usize;
    }
    println!("200 requests served, {correct} correct");
    println!("metrics: {}", handle.metrics.summary());
    Ok(())
}
