//! Post-training factorization walkthrough (the paper's second use case).
//!
//! ```bash
//! make artifacts && cargo run --release --example post_training
//! ```
//!
//! Trains the dense CNN on the `shapes` image task, then factorizes the
//! *trained* checkpoint at several rank ratios with SVD and with Random —
//! demonstrating the paper's §Design warning: Random "may break what the
//! model learnt" post-training, while SVD preserves most of the accuracy.

use greenformer::data::image::{ShapesTask, HW};
use greenformer::eval::eval_classifier;
use greenformer::factorize::{auto_fact, AutoFactConfig, Rank, Solver};
use greenformer::runtime::Engine;
use greenformer::train::Trainer;

fn main() -> greenformer::Result<()> {
    let steps: usize = std::env::var("GREENFORMER_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);
    let engine = Engine::load_default()?;
    let ds = ShapesTask::new(42);
    let hw = Some((HW, HW, 1usize));

    println!("=== training image/dense on shapes ({steps} steps) ===");
    let mut trainer = Trainer::from_init(&engine, "image", "dense")?;
    trainer.train_classifier(&ds, steps, hw, |log| {
        if log.step % 25 == 0 {
            println!("  step {:>4}  loss {:.4}", log.step, log.loss);
        }
    })?;
    let dense = trainer.params.clone();
    let fwd = engine.manifest().find("image", "dense", "fwd", None)?.clone();
    let ev = eval_classifier(&engine, &fwd, &dense, &ds, 512, hw)?;
    println!("dense eval acc: {:.3}\n", ev.accuracy());

    println!("ratio  solver  rank-decisions  params  acc    rel-perf");
    for ratio in [0.75, 0.50, 0.25, 0.10] {
        for solver in [Solver::Svd, Solver::Random] {
            let mut fact = dense.clone();
            let report = auto_fact(
                &mut fact,
                &AutoFactConfig {
                    rank: Rank::Ratio(ratio),
                    solver,
                    num_iter: 50,
                    submodules: None,
                    ..Default::default()
                },
            )?;
            let variant = format!("led_r{:02}", (ratio * 100.0).round() as usize);
            let g = engine.manifest().find("image", &variant, "fwd", None)?.clone();
            let ev_f = eval_classifier(&engine, &g, &fact, &ds, 512, hw)?;
            println!(
                "{ratio:<5.2}  {:<6}  {:<14} {:<7} {:.3}  {:.3}",
                solver.to_string(),
                report.n_factorized(),
                fact.n_params(),
                ev_f.accuracy(),
                ev_f.accuracy() / ev.accuracy()
            );
        }
    }
    println!("\nExpected shape (paper §Design): SVD degrades gracefully with ratio;");
    println!("Random collapses to chance post-training at every ratio.");
    Ok(())
}
