//! Quickstart (E4): the paper's Figure-1 one-liner, end to end.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Loads a dense text-classifier checkpoint, factorizes it with one
//! `auto_fact` call (SVD, rank ratio 0.25), and runs both the dense and the
//! factorized model through the PJRT engine on the same batch — showing the
//! LED model is smaller, faster, and (being SVD-initialized from the same
//! weights) produces nearby logits.

use greenformer::data::text::PolarityTask;
use greenformer::data::{batch, Split};
use greenformer::eval::measure_latency;
use greenformer::factorize::{auto_fact, AutoFactConfig, Rank, Solver};
use greenformer::runtime::Engine;
use greenformer::tensor::ParamStore;

fn main() -> greenformer::Result<()> {
    let engine = Engine::load_default()?;
    println!("PJRT platform: {}", engine.platform());

    // 1. A dense model checkpoint (the JAX-exported init).
    let ckpt = engine.manifest().checkpoint("text", "dense")?;
    let dense = ParamStore::load_gtz(ckpt)?;
    println!("dense model: {} params", dense.n_params());

    // 2. The Greenformer one-liner.
    let mut fact = dense.clone();
    let report = auto_fact(
        &mut fact,
        &AutoFactConfig {
            rank: Rank::Ratio(0.25),
            solver: Solver::Svd,
            num_iter: 50,
            submodules: None,
            ..Default::default()
        },
    )?;
    print!("{report}");

    // 3. Run both through the engine on the same batch.
    let ds = PolarityTask::new(64, 42);
    let dense_graph = engine.manifest().find("text", "dense", "fwd", Some(8))?.clone();
    let fact_graph = engine.manifest().find("text", "led_r25", "fwd", Some(8))?.clone();
    let (x, _) = batch(&ds, Split::Eval, 0, dense_graph.batch, None);

    let dense_out = engine.run_fwd(&dense_graph, &dense, &[x.clone()])?;
    let fact_out = engine.run_fwd(&fact_graph, &fact, &[x.clone()])?;
    let (d, f) = (dense_out[0].as_f32()?, fact_out[0].as_f32()?);
    let max_dev = d
        .iter()
        .zip(f)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("max |logit(dense) - logit(led_r25)| on one batch: {max_dev:.4}");

    // 4. Latency comparison (median of 20).
    let lat_d = measure_latency(&engine, &dense_graph, &dense, &[x.clone()], 3, 20)?;
    let lat_f = measure_latency(&engine, &fact_graph, &fact, &[x], 3, 20)?;
    println!(
        "latency: dense {:.2} ms, led_r25 {:.2} ms -> {:.2}x speedup",
        lat_d * 1e3,
        lat_f * 1e3,
        lat_d / lat_f
    );
    println!(
        "params:  dense {}, led_r25 {} -> {:.1}% size",
        dense.n_params(),
        fact.n_params(),
        100.0 * fact.n_params() as f64 / dense.n_params() as f64
    );
    Ok(())
}
