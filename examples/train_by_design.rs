//! End-to-end validation driver (E8): factorization-by-design training.
//!
//! ```bash
//! make artifacts && cargo run --release --example train_by_design
//! ```
//!
//! Trains the transformer text classifier on the `polarity` task for a few
//! hundred steps, twice — dense baseline and LED at rank ratio 0.25 — using
//! the fused AOT train graphs (fwd + bwd through the Pallas custom VJPs +
//! Adam, all inside XLA; Rust only drives). Logs both loss curves, then
//! evaluates held-out accuracy and forward latency. This is the run recorded
//! in EXPERIMENTS.md §E8.
//!
//! Env: GREENFORMER_STEPS (default 300).

use greenformer::data::text::PolarityTask;
use greenformer::data::{batch, Split};
use greenformer::eval::{eval_classifier, measure_latency};
use greenformer::runtime::Engine;
use greenformer::train::{checkpoint, Trainer};

fn main() -> greenformer::Result<()> {
    let steps: usize = std::env::var("GREENFORMER_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let engine = Engine::load_default()?;
    let ds = PolarityTask::new(64, 42);

    let mut results = Vec::new();
    for variant in ["dense", "led_r25"] {
        println!("=== training text/{variant} on polarity ({steps} steps) ===");
        let mut trainer = Trainer::from_init(&engine, "text", variant)?;
        println!("params: {}", trainer.params.n_params());
        trainer.train_classifier(&ds, steps, None, |log| {
            if log.step % 20 == 0 || log.step == 1 {
                println!(
                    "  step {:>4}  loss {:.4}  ({:.0} ms/step)",
                    log.step,
                    log.loss,
                    log.seconds * 1e3
                );
            }
        })?;

        let fwd = engine.manifest().find("text", variant, "fwd", None)?.clone();
        let ev = eval_classifier(&engine, &fwd, &trainer.params, &ds, 512, None)?;
        let (x, _) = batch(&ds, Split::Eval, 0, fwd.batch, None);
        let lat = measure_latency(&engine, &fwd, &trainer.params, &[x], 3, 20)?;
        println!(
            "{variant}: final loss {:.4}, eval acc {:.3}, fwd {:.2} ms/batch\n",
            trainer.recent_loss(20),
            ev.accuracy(),
            lat * 1e3
        );
        checkpoint::save("runs", &format!("by_design_{variant}"), &trainer.params)?;
        results.push((variant, trainer.recent_loss(20), ev.accuracy(), lat));
    }

    println!("=== summary (E8) ===");
    println!("variant   loss    acc    latency");
    for (v, loss, acc, lat) in &results {
        println!("{v:<9} {loss:.4}  {acc:.3}  {:.2} ms", lat * 1e3);
    }
    let (dense, led) = (&results[0], &results[1]);
    println!(
        "led_r25 vs dense: rel-perf {:.3}, speedup {:.2}x",
        led.2 / dense.2,
        dense.3 / led.3
    );
    Ok(())
}
